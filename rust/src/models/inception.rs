//! Inception-V3-like benchmark graph (paper §5.1).
//!
//! Reproduces the *structure* of Inception-V3 as a module DAG: conv stem,
//! 11 Inception blocks with 4 parallel branches each (1×1, 5×5, double-3×3
//! and pool-projection), grid reductions, global pool and the final
//! classifier. Each convolution expands into TF-granularity micro-ops
//! (kernel variable + conv + batch-norm + activation + plumbing), so the
//! unoptimized operator graph lands near the paper's ~6.9 k ops (Table 6)
//! and fuses down to a few hundred groups.

use super::common::{bytes_f32, conv_flops, CostModel, ModelBuilder, ModuleSpec};
use crate::graph::{OpGraph, OpKind};

/// Spatial/channel shape tracked while building.
#[derive(Clone, Copy)]
struct Feat {
    h: usize,
    w: usize,
    c: usize,
}

/// One conv module at TF granularity: conv, bias-add, four batch-norm
/// stages (mean/var/scale/shift), activation, and shape plumbing ops.
const MICRO_PER_CONV: usize = 12;
/// Kernel, BN gamma/beta, BN moving stats.
const VARS_PER_CONV: usize = 4;

fn conv(
    b: &mut ModelBuilder,
    name: &str,
    batch: usize,
    input: Feat,
    cout: usize,
    k: usize,
    stride: usize,
    deps: &[usize],
) -> (usize, Feat) {
    let out = Feat {
        h: (input.h + stride - 1) / stride,
        w: (input.w + stride - 1) / stride,
        c: cout,
    };
    let flops = conv_flops(batch, out.h, out.w, input.c, cout, k, k);
    let params = bytes_f32(&[k, k, input.c, cout]) + bytes_f32(&[4, cout]);
    let output = bytes_f32(&[batch, out.h, out.w, cout]);
    // conv scratch ≈ im2col patch buffer
    let temp = bytes_f32(&[batch, out.h, out.w, k * k * input.c]).min(256 << 20);
    let idx = b.add_module(
        ModuleSpec::new(name, OpKind::Conv2d)
            .micro(MICRO_PER_CONV)
            .vars(VARS_PER_CONV)
            .flops(flops)
            .params(params)
            .output(output)
            .temp(temp),
        deps,
    );
    (idx, out)
}

fn pool(
    b: &mut ModelBuilder,
    name: &str,
    batch: usize,
    input: Feat,
    stride: usize,
    deps: &[usize],
) -> (usize, Feat) {
    let out = Feat {
        h: (input.h + stride - 1) / stride,
        w: (input.w + stride - 1) / stride,
        c: input.c,
    };
    let output = bytes_f32(&[batch, out.h, out.w, out.c]);
    let idx = b.add_module(
        ModuleSpec::new(name, OpKind::Pool)
            .micro(2)
            .flops(output as f64)
            .output(output),
        deps,
    );
    (idx, out)
}

fn concat(b: &mut ModelBuilder, name: &str, batch: usize, f: Feat, deps: &[usize]) -> usize {
    let output = bytes_f32(&[batch, f.h, f.w, f.c]);
    b.add_module(
        ModuleSpec::new(name, OpKind::Shape)
            .micro(1)
            .flops(0.0)
            .output(output),
        deps,
    )
}

/// An Inception block with four branches; returns (module, out feat).
#[allow(clippy::too_many_arguments)]
fn inception_block(
    b: &mut ModelBuilder,
    name: &str,
    batch: usize,
    input: Feat,
    dep: usize,
    b1x1: usize,
    b5_red: usize,
    b5: usize,
    b3_red: usize,
    b3: usize,
    bpool: usize,
) -> (usize, Feat) {
    // branch 1: 1x1
    let (m1, _) = conv(b, &format!("{name}/b1/c1x1"), batch, input, b1x1, 1, 1, &[dep]);
    // branch 2: 1x1 → 5x5
    let (m2a, f2a) = conv(b, &format!("{name}/b2/red"), batch, input, b5_red, 1, 1, &[dep]);
    let (m2, _) = conv(b, &format!("{name}/b2/c5x5"), batch, f2a, b5, 5, 1, &[m2a]);
    // branch 3: 1x1 → 3x3 → 3x3
    let (m3a, f3a) = conv(b, &format!("{name}/b3/red"), batch, input, b3_red, 1, 1, &[dep]);
    let (m3b, f3b) = conv(b, &format!("{name}/b3/c3a"), batch, f3a, b3, 3, 1, &[m3a]);
    let (m3, _) = conv(b, &format!("{name}/b3/c3b"), batch, f3b, b3, 3, 1, &[m3b]);
    // branch 4: pool → 1x1
    let (m4a, f4a) = pool(b, &format!("{name}/b4/pool"), batch, input, 1, &[dep]);
    let (m4, _) = conv(b, &format!("{name}/b4/proj"), batch, f4a, bpool, 1, 1, &[m4a]);
    let out = Feat {
        h: input.h,
        w: input.w,
        c: b1x1 + b5 + b3 + bpool,
    };
    let cat = concat(b, &format!("{name}/concat"), batch, out, &[m1, m2, m3, m4]);
    (cat, out)
}

/// Grid-reduction block (stride-2 branches + pool), halving the grid.
fn reduction_block(
    b: &mut ModelBuilder,
    name: &str,
    batch: usize,
    input: Feat,
    dep: usize,
    c3: usize,
    c3d_red: usize,
    c3d: usize,
) -> (usize, Feat) {
    let (m1, f1) = conv(b, &format!("{name}/b1/c3s2"), batch, input, c3, 3, 2, &[dep]);
    let (m2a, f2a) = conv(b, &format!("{name}/b2/red"), batch, input, c3d_red, 1, 1, &[dep]);
    let (m2b, f2b) = conv(b, &format!("{name}/b2/c3"), batch, f2a, c3d, 3, 1, &[m2a]);
    let (m2, _) = conv(b, &format!("{name}/b2/c3s2"), batch, f2b, c3d, 3, 2, &[m2b]);
    let (m3, _) = pool(b, &format!("{name}/b3/pool"), batch, input, 2, &[dep]);
    let out = Feat {
        h: f1.h,
        w: f1.w,
        c: c3 + c3d + input.c,
    };
    let cat = concat(b, &format!("{name}/concat"), batch, out, &[m1, m2, m3]);
    (cat, out)
}

/// Build the Inception-V3 training graph for a batch size.
pub fn inception_v3(batch: usize) -> OpGraph {
    let mut b = ModelBuilder::new(&format!("inception_v3_bs{batch}"), CostModel::default());
    let mut f = Feat { h: 299, w: 299, c: 3 };
    let x = b.add_input("input", bytes_f32(&[batch, f.h, f.w, f.c]));

    // Stem: 5 convs + 2 pools.
    let (m, nf) = conv(&mut b, "stem/c1", batch, f, 32, 3, 2, &[x]);
    f = nf;
    let (m, nf) = conv(&mut b, "stem/c2", batch, f, 32, 3, 1, &[m]);
    f = nf;
    let (m, nf) = conv(&mut b, "stem/c3", batch, f, 64, 3, 1, &[m]);
    f = nf;
    let (m, nf) = pool(&mut b, "stem/pool1", batch, f, 2, &[m]);
    f = nf;
    let (m, nf) = conv(&mut b, "stem/c4", batch, f, 80, 1, 1, &[m]);
    f = nf;
    let (m, nf) = conv(&mut b, "stem/c5", batch, f, 192, 3, 1, &[m]);
    f = nf;
    let (mut m, nf) = pool(&mut b, "stem/pool2", batch, f, 2, &[m]);
    f = nf;

    // 3 × block A (35×35).
    for i in 0..3 {
        let (nm, nf) = inception_block(
            &mut b,
            &format!("mixedA{i}"),
            batch,
            f,
            m,
            64,
            48,
            64,
            64,
            96,
            if i == 0 { 32 } else { 64 },
        );
        m = nm;
        f = nf;
    }
    // Reduction A → 17×17.
    let (nm, nf) = reduction_block(&mut b, "redA", batch, f, m, 384, 64, 96);
    m = nm;
    f = nf;
    // 4 × block B (17×17).
    for i in 0..4 {
        let ch = [128, 160, 160, 192][i];
        let (nm, nf) = inception_block(
            &mut b,
            &format!("mixedB{i}"),
            batch,
            f,
            m,
            192,
            ch,
            192,
            ch,
            192,
            192,
        );
        m = nm;
        f = nf;
    }
    // Reduction B → 8×8.
    let (nm, nf) = reduction_block(&mut b, "redB", batch, f, m, 320, 192, 192);
    m = nm;
    f = nf;
    // 2 × block C (8×8).
    for i in 0..2 {
        let (nm, nf) = inception_block(
            &mut b,
            &format!("mixedC{i}"),
            batch,
            f,
            m,
            320,
            384,
            384,
            448,
            384,
            192,
        );
        m = nm;
        f = nf;
    }
    // Head: global pool + FC + loss.
    let (gp, _) = pool(&mut b, "head/gap", batch, f, f.h, &[m]);
    let fc = b.add_module(
        ModuleSpec::new("head/fc", OpKind::MatMul)
            .micro(3)
            .vars(2)
            .flops(super::common::matmul_flops(batch, f.c, 1000))
            .params(bytes_f32(&[f.c, 1000]))
            .output(bytes_f32(&[batch, 1000]))
            .temp(bytes_f32(&[batch, 1000])),
        &[gp],
    );
    let loss = b.add_module(
        ModuleSpec::new("loss", OpKind::Loss)
            .micro(3)
            .flops(batch as f64 * 1000.0 * 8.0)
            .output(4)
            .temp(bytes_f32(&[batch, 1000]) * 2),
        &[fc],
    );
    b.build_training_graph(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_and_scale() {
        let g = inception_v3(32);
        assert!(g.is_acyclic());
        // Paper Table 6: unoptimized Inception-V3 ≈ 6.9k ops. The module
        // granularity here yields the same order of magnitude.
        assert!(g.len() > 1500, "got {} ops", g.len());
        assert!(g.len() < 20_000, "got {} ops", g.len());
        // Both forward and backward ops exist.
        let bwd = g.iter_nodes().filter(|n| n.is_backward).count();
        assert!(bwd > 500);
    }

    #[test]
    fn memory_scales_with_batch() {
        let g32 = inception_v3(32);
        let g64 = inception_v3(64);
        let m32 = g32.total_permanent_memory();
        let m64 = g64.total_permanent_memory();
        // activations dominate → roughly 2× permanent (outputs) growth
        assert!(m64 > m32, "{m64} vs {m32}");
        // params are batch-independent, so growth is sub-2×.
        assert!((m64 as f64) < 2.2 * m32 as f64);
    }

    #[test]
    fn fits_8gb_single_not_2_4gb() {
        // The paper's Table 4/5 regime: single 8 GB device holds the
        // model; a 2.4 GB (30 %) device does not.
        let g = inception_v3(32);
        let peak_lower_bound = g.total_permanent_memory();
        assert!(
            peak_lower_bound < 8_000_000_000,
            "permanent {} should fit 8 GB",
            peak_lower_bound
        );
        assert!(
            peak_lower_bound > 2_400_000_000,
            "permanent {} should exceed 2.4 GB",
            peak_lower_bound
        );
    }

    #[test]
    fn compute_magnitude_sane() {
        let g = inception_v3(32);
        let total = g.total_compute();
        // Single-GPU step time in the paper is 0.269 s; our cost model
        // should land within a small factor.
        assert!(total > 0.05, "total {total}");
        assert!(total < 2.0, "total {total}");
    }

    #[test]
    fn colocation_groups_are_small() {
        let g = inception_v3(32);
        for (name, members) in g.colocation_groups() {
            assert!(members.len() == 2, "group {name} has {}", members.len());
        }
    }
}
