//! Small MLP whose layers correspond 1:1 to the AOT HLO artifacts
//! produced by `python/compile/model.py` — the model the end-to-end
//! example *really trains* on the multi-device executor.
//!
//! Layer names here must match the artifact manifest: `layer{i}_fwd`,
//! `layer{i}_bwd`, `loss_fwd`, `loss_bwd` (see `python/compile/aot.py`).

use super::common::{bytes_f32, matmul_flops, CostModel, ModelBuilder, ModuleSpec};
use crate::graph::{OpGraph, OpKind};

/// MLP configuration; defaults mirror the e2e example.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub batch: usize,
    pub dims: Vec<usize>,
    pub classes: usize,
}

impl Default for MlpConfig {
    fn default() -> MlpConfig {
        MlpConfig {
            batch: 64,
            dims: vec![64, 128, 128, 64],
            classes: 10,
        }
    }
}

impl MlpConfig {
    /// Layer (in, out) dims, including the classifier layer.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for w in self.dims.windows(2) {
            v.push((w[0], w[1]));
        }
        v.push((*self.dims.last().unwrap(), self.classes));
        v
    }
}

/// Build the module-level MLP training graph matching the artifacts.
pub fn mlp(cfg: &MlpConfig) -> OpGraph {
    let mut b = ModelBuilder::new("mlp", CostModel::default());
    let x = b.add_input("input", bytes_f32(&[cfg.batch, cfg.dims[0]]));
    let mut prev = x;
    for (i, (din, dout)) in cfg.layer_dims().into_iter().enumerate() {
        prev = b.add_module(
            ModuleSpec::new(&format!("layer{i}"), OpKind::MatMul)
                .micro(1) // module == one artifact call
                .vars(1)
                .flops(matmul_flops(cfg.batch, din, dout))
                .params(bytes_f32(&[din, dout]) + bytes_f32(&[dout]))
                .output(bytes_f32(&[cfg.batch, dout]))
                .temp(bytes_f32(&[cfg.batch, dout])),
            &[prev],
        );
    }
    let loss = b.add_module(
        ModuleSpec::new("loss", OpKind::Loss)
            .micro(1)
            .flops((cfg.batch * cfg.classes) as f64 * 4.0)
            .output(4)
            .temp(bytes_f32(&[cfg.batch, cfg.classes])),
        &[prev],
    );
    b.build_training_graph(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_matches_artifacts() {
        let cfg = MlpConfig::default();
        let g = mlp(&cfg);
        assert!(g.is_acyclic());
        // 4 layers + loss: each layer = var + fwd + bwd + apply
        let fwd_layers = g
            .iter_nodes()
            .filter(|n| n.name.contains("layer") && n.name.contains("fwd"))
            .count();
        assert_eq!(fwd_layers, 4);
        let bwd_layers = g
            .iter_nodes()
            .filter(|n| n.name.contains("layer") && n.name.contains("bwd"))
            .count();
        assert_eq!(bwd_layers, 4);
    }

    #[test]
    fn layer_dims_include_classifier() {
        let cfg = MlpConfig::default();
        let dims = cfg.layer_dims();
        assert_eq!(dims.len(), 4);
        assert_eq!(dims[0], (64, 128));
        assert_eq!(dims[3], (64, 10));
    }
}
