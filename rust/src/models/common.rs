//! Shared machinery for the synthetic benchmark-graph generators.
//!
//! The paper profiles real TensorFlow/PyTorch graphs; our substitution
//! (DESIGN.md §2) generates graphs with the same *structure* (module DAG,
//! op expansion granularity, colocation/co-placement groups, fwd/bwd
//! pairing) and *cost distributions* (an analytic GPU cost model with
//! per-op launch overhead, so unoptimized graphs have the paper's ρ ≫ 1).
//!
//! A model is declared as a DAG of **modules** (PyTorch granularity); each
//! module expands into a chain of **micro-ops** (TensorFlow granularity):
//! variable ops (carrying parameters, colocation-constrained with their
//! ApplyGrad), a forward compute chain, a mirrored backward chain, and an
//! optimizer op. [`ModelBuilder::build_training_graph`] materializes the
//! full fwd+bwd operator graph.

use crate::graph::{MemorySpec, NodeId, OpGraph, OpKind};

/// Analytic device cost model (GTX-2080-like, DESIGN.md §2).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Sustained FLOP/s for large dense ops.
    pub flops_per_sec: f64,
    /// Fixed per-kernel launch overhead, seconds. This is what makes
    /// thousands of tiny TF ops expensive and drives the paper's
    /// optimization gains (Table 6).
    pub launch_overhead: f64,
    /// Sustained memory bandwidth for elementwise ops, bytes/s.
    pub mem_bw: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel {
            flops_per_sec: 6.0e12,
            launch_overhead: 8.0e-6,
            mem_bw: 350.0e9,
        }
    }
}

impl CostModel {
    /// Time for a dense op of `flops` touching `bytes` of memory.
    pub fn op_time(&self, flops: f64, bytes: u64) -> f64 {
        self.launch_overhead + (flops / self.flops_per_sec).max(bytes as f64 / self.mem_bw)
    }
}

/// Reference op costs for device-speed calibration
/// ([`crate::calibrate`]): a spread of operator shapes under the default
/// analytic cost model, from launch-overhead-dominated micro-ops to
/// FLOP-dominated dense matmuls. A device's fitted speed factor is the
/// median ratio of these reference costs to its measured times — 1.0
/// means the device matches the profiling model exactly.
pub fn calibration_probe_costs() -> Vec<f64> {
    let c = CostModel::default();
    vec![
        // Launch-overhead floor: a no-op kernel.
        c.op_time(0.0, 0),
        // Tiny elementwise op (memory-bound).
        c.op_time(1e6, 64 << 10),
        // Mid-size matmul (512³, compute-bound).
        c.op_time(2.0 * 512f64.powi(3), 1 << 20),
        // Large matmul (2048³) — the steady-state throughput probe.
        c.op_time(2.0 * 2048f64.powi(3), 32 << 20),
    ]
}

/// Declarative module description.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: OpKind,
    /// Number of micro-ops the forward compute expands into (TF
    /// granularity). The backward chain mirrors this count.
    pub micro_ops: usize,
    /// Number of variable (parameter) ops.
    pub var_ops: usize,
    /// Forward FLOPs of the whole module.
    pub flops: f64,
    /// Parameter bytes (split across variable ops).
    pub params: u64,
    /// Output tensor bytes (what successors receive).
    pub output: u64,
    /// Scratch bytes used while computing.
    pub temp: u64,
}

impl ModuleSpec {
    pub fn new(name: &str, kind: OpKind) -> ModuleSpec {
        ModuleSpec {
            name: name.to_string(),
            kind,
            micro_ops: 1,
            var_ops: 0,
            flops: 0.0,
            params: 0,
            output: 0,
            temp: 0,
        }
    }

    pub fn micro(mut self, n: usize) -> Self {
        self.micro_ops = n.max(1);
        self
    }
    pub fn vars(mut self, n: usize) -> Self {
        self.var_ops = n;
        self
    }
    pub fn flops(mut self, f: f64) -> Self {
        self.flops = f;
        self
    }
    pub fn params(mut self, b: u64) -> Self {
        self.params = b;
        self
    }
    pub fn output(mut self, b: u64) -> Self {
        self.output = b;
        self
    }
    pub fn temp(mut self, b: u64) -> Self {
        self.temp = b;
        self
    }
}

/// A materialized module: the op ids it expanded into.
#[derive(Debug, Clone)]
pub struct ModuleInst {
    pub spec: ModuleSpec,
    /// First forward compute op (receives inputs).
    pub fwd_in: NodeId,
    /// Last forward compute op (produces the module output).
    pub fwd_out: NodeId,
    /// All forward compute ops, in chain order.
    pub fwd_ops: Vec<NodeId>,
    /// Variable ops.
    pub var_ops: Vec<NodeId>,
    /// Backward ops (filled by `build_training_graph`), reverse order.
    pub bwd_ops: Vec<NodeId>,
    /// Gradient output op of the backward chain (feeds deps' backward).
    pub bwd_out: Option<NodeId>,
}

/// Module-DAG builder that expands to the operator graph.
pub struct ModelBuilder {
    pub graph: OpGraph,
    pub cost: CostModel,
    modules: Vec<ModuleInst>,
    /// Module-level edges (dep → consumer, forward bytes).
    edges: Vec<(usize, usize, u64)>,
}

impl ModelBuilder {
    pub fn new(name: &str, cost: CostModel) -> ModelBuilder {
        ModelBuilder {
            graph: OpGraph::new(name),
            cost,
            modules: Vec::new(),
            edges: Vec::new(),
        }
    }

    pub fn module_count(&self) -> usize {
        self.modules.len()
    }

    pub fn module(&self, idx: usize) -> &ModuleInst {
        &self.modules[idx]
    }

    /// Expand a module and wire it after its dependencies. Returns the
    /// module index.
    pub fn add_module(&mut self, spec: ModuleSpec, deps: &[usize]) -> usize {
        let deps: Vec<(usize, Option<u64>)> = deps.iter().map(|&d| (d, None)).collect();
        self.add_module_edges(spec, &deps)
    }

    /// Like [`Self::add_module`], but each dependency may override the
    /// bytes its edge carries — e.g. an unrolled cell consumes only its
    /// time-step *slice* of the embedding output, not the whole tensor.
    pub fn add_module_edges(&mut self, spec: ModuleSpec, deps: &[(usize, Option<u64>)]) -> usize {
        let n_micro = spec.micro_ops;
        let per_op_flops = spec.flops / n_micro as f64;
        // Intermediate micro-op outputs are a fraction of the module
        // output (bias/BN/activation stages reuse or slim the tensor);
        // the final op carries the real output tensor. The ratio is
        // calibrated so training peaks land in the paper's regime
        // (Inception bs32 ≈ 2.5–4 GiB, bs64 < 8 GiB on one device).
        let inter_bytes = (spec.output / 16).max(4);
        let per_op_temp = spec.temp / n_micro as u64;

        // Variable ops: hold parameters, colocation-constrained (§3.1.1).
        let mut var_ids = Vec::new();
        for v in 0..spec.var_ops {
            let id = self
                .graph
                .add_node(&format!("{}/var{}", spec.name, v), OpKind::Variable);
            let n = self.graph.node_mut(id);
            let share = spec.params / spec.var_ops as u64;
            n.mem = MemorySpec {
                params: share,
                param_grad: share,
                ..Default::default()
            };
            n.compute = 1.0e-6; // variable read is nearly free
            n.output_bytes = share;
            n.colocation_group = Some(format!("{}/colo{}", spec.name, v));
            n.coplacement_group = Some(spec.name.clone());
            var_ids.push(id);
        }

        // Forward compute chain.
        let mut fwd_ops = Vec::new();
        for i in 0..n_micro {
            let last = i == n_micro - 1;
            let id = self
                .graph
                .add_node(&format!("{}/fwd{}", spec.name, i), spec.kind.clone());
            let out_bytes = if last { spec.output } else { inter_bytes };
            let n = self.graph.node_mut(id);
            n.compute = self.cost.op_time(per_op_flops, out_bytes + per_op_temp);
            n.mem = MemorySpec {
                output: out_bytes,
                upstream_grad: out_bytes,
                temp: per_op_temp,
                ..Default::default()
            };
            n.output_bytes = out_bytes;
            n.coplacement_group = Some(spec.name.clone());
            if let Some(&prev) = fwd_ops.last() {
                self.graph.add_edge(prev, id, inter_bytes);
            }
            fwd_ops.push(id);
        }
        // Wire variables into the first compute op.
        for &v in &var_ids {
            let bytes = self.graph.node(v).output_bytes;
            self.graph.add_edge(v, fwd_ops[0], bytes);
        }
        // Wire dependencies.
        for &(d, byte_override) in deps {
            let dep_out = self.modules[d].fwd_out;
            let bytes = byte_override.unwrap_or(self.graph.node(dep_out).output_bytes);
            self.graph.add_edge(dep_out, fwd_ops[0], bytes);
            self.edges.push((d, self.modules.len(), bytes));
        }

        self.modules.push(ModuleInst {
            spec,
            fwd_in: fwd_ops[0],
            fwd_out: *fwd_ops.last().unwrap(),
            fwd_ops,
            var_ops: var_ids,
            bwd_ops: Vec::new(),
            bwd_out: None,
        });
        self.modules.len() - 1
    }

    /// Convenience: input module (no params, no backward).
    pub fn add_input(&mut self, name: &str, bytes: u64) -> usize {
        self.add_module(
            ModuleSpec::new(name, OpKind::Input)
                .output(bytes)
                .flops(0.0),
            &[],
        )
    }

    /// Generate the mirrored backward graph plus optimizer ops, producing
    /// the full training graph. `loss_module` must be the unique sink.
    ///
    /// Backward of module `m` consumes the upstream gradients from the
    /// backward of every consumer of `m`, plus `m`'s forward output
    /// (residuals); each backward micro-op is tagged with `forward_of` its
    /// mirrored forward op for the co-placement heuristic (§3.1.2). Each
    /// variable gets an ApplyGrad op colocation-constrained with it
    /// (§3.1.1) fed by the module's backward chain.
    pub fn build_training_graph(mut self, loss_module: usize) -> OpGraph {
        // Consumers per module, with the forward bytes each consumed.
        let mut consumers: Vec<Vec<(usize, u64)>> = vec![Vec::new(); self.modules.len()];
        for &(dep, cons, bytes) in &self.edges {
            consumers[dep].push((cons, bytes));
        }
        // Module-level reverse topological order = reverse of insertion
        // order (modules can only depend on earlier modules).
        let order: Vec<usize> = (0..self.modules.len()).rev().collect();

        for &mi in &order {
            let (spec, fwd_ops, var_ids, fwd_out) = {
                let m = &self.modules[mi];
                (
                    m.spec.clone(),
                    m.fwd_ops.clone(),
                    m.var_ops.clone(),
                    m.fwd_out,
                )
            };
            if matches!(spec.kind, OpKind::Input) {
                continue; // inputs need no gradient
            }
            let n_micro = fwd_ops.len();
            // Backward flops ≈ 2× forward (dX and dW matmuls).
            let per_op_flops = 2.0 * spec.flops / n_micro as f64;
            let grad_bytes = spec.output.max(4);
            let inter_bytes = (grad_bytes / 4).max(4);

            let mut bwd_ops = Vec::new();
            for i in 0..n_micro {
                let fwd_match = fwd_ops[n_micro - 1 - i];
                let id = self
                    .graph
                    .add_node(&format!("{}/bwd{}", spec.name, i), spec.kind.clone());
                let out_bytes = if i == n_micro - 1 {
                    // gradient w.r.t. module input
                    grad_bytes
                } else {
                    inter_bytes
                };
                let n = self.graph.node_mut(id);
                n.compute = self.cost.op_time(per_op_flops, out_bytes);
                n.mem = MemorySpec {
                    upstream_grad: out_bytes,
                    temp: spec.temp / n_micro as u64,
                    ..Default::default()
                };
                n.output_bytes = out_bytes;
                n.is_backward = true;
                n.forward_of = Some(fwd_match);
                n.coplacement_group = Some(spec.name.clone());
                if let Some(&prev) = bwd_ops.last() {
                    self.graph.add_edge(prev, id, inter_bytes);
                }
                bwd_ops.push(id);
            }
            // Residual edges: every forward micro-op's activation is
            // consumed by its mirrored backward op, so activations stay
            // resident until the backward pass reaches them — the memory
            // behaviour that makes training peaks several × inference
            // peaks (paper Table 2 / §4.2).
            for (i, &b) in bwd_ops.iter().enumerate() {
                let fwd_match = fwd_ops[n_micro - 1 - i];
                let bytes = self.graph.node(fwd_match).output_bytes;
                self.graph.add_edge(fwd_match, b, bytes);
            }
            let _ = fwd_out;
            // Upstream gradients from consumers' backward chains carry
            // ∂L/∂out_m — sized by *this* module's output, not by the
            // consumer's gradient (a classifier's bwd sends each LSTM
            // cell a hidden-sized slice, not the logits-sized tensor).
            // The loss module's backward starts from its own forward.
            if mi != loss_module {
                // Each consumer's backward returns the gradient of what
                // it consumed — sized by the *forward edge*. Variable
                // modules (shared weights read by many unrolled
                // consumers) receive pre-aggregated gradient shards
                // instead: TF reduces each device's dW contributions
                // with a local AddN before shipping, so the wire carries
                // ≈ one weight tensor total, not one per consumer.
                let n_consumers = consumers[mi].len().max(1) as u64;
                for &(c, fwd_bytes) in &consumers[mi] {
                    if let Some(cb) = self.modules[c].bwd_out {
                        let grad_bytes = if matches!(spec.kind, OpKind::Variable) {
                            (fwd_bytes / n_consumers).max(4)
                        } else {
                            fwd_bytes.max(4)
                        };
                        self.graph.add_edge(cb, bwd_ops[0], grad_bytes);
                    }
                }
            }
            // ApplyGrad per variable, TF-colocation-constrained with it.
            let bwd_last = *bwd_ops.last().unwrap();
            for (v, &var) in var_ids.iter().enumerate() {
                let id = self
                    .graph
                    .add_node(&format!("{}/apply{}", spec.name, v), OpKind::ApplyGrad);
                let share = spec.params / spec.var_ops.max(1) as u64;
                let var_colo = self.graph.node(var).colocation_group.clone();
                let n = self.graph.node_mut(id);
                n.compute = self.cost.op_time(share as f64 / 2.0, share);
                n.mem = MemorySpec {
                    temp: share / 2,
                    ..Default::default()
                };
                n.output_bytes = 4;
                n.is_backward = true;
                n.colocation_group = var_colo;
                n.coplacement_group = Some(spec.name.clone());
                let gb = self.graph.node(bwd_last).output_bytes;
                self.graph.add_edge(bwd_last, id, gb);
            }
            let m = &mut self.modules[mi];
            m.bwd_out = Some(bwd_last);
            m.bwd_ops = bwd_ops;
        }
        debug_assert!(self.graph.is_acyclic(), "training graph has a cycle");
        self.graph
    }

    /// Forward-only graph (inference), without backward generation.
    pub fn build_forward_graph(self) -> OpGraph {
        debug_assert!(self.graph.is_acyclic());
        self.graph
    }
}

/// f32 tensor bytes for a shape.
pub fn bytes_f32(dims: &[usize]) -> u64 {
    4 * dims.iter().product::<usize>() as u64
}

/// FLOPs of a dense `m×k · k×n` matmul.
pub fn matmul_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// FLOPs of a conv as implicit GEMM: output `h×w×cout`, kernel `kh×kw×cin`.
pub fn conv_flops(batch: usize, h: usize, w: usize, cin: usize, cout: usize, kh: usize, kw: usize) -> f64 {
    2.0 * batch as f64 * h as f64 * w as f64 * cout as f64 * (kh * kw * cin) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> OpGraph {
        let mut b = ModelBuilder::new("tiny", CostModel::default());
        let x = b.add_input("x", bytes_f32(&[32, 64]));
        let l1 = b.add_module(
            ModuleSpec::new("dense1", OpKind::MatMul)
                .micro(3)
                .vars(2)
                .flops(matmul_flops(32, 64, 128))
                .params(bytes_f32(&[64, 128]))
                .output(bytes_f32(&[32, 128]))
                .temp(1024),
            &[x],
        );
        let loss = b.add_module(
            ModuleSpec::new("loss", OpKind::Loss)
                .micro(2)
                .flops(1e4)
                .output(4),
            &[l1],
        );
        b.build_training_graph(loss)
    }

    #[test]
    fn expansion_counts() {
        let g = tiny_model();
        // x: 1 fwd; dense1: 2 vars + 3 fwd + 3 bwd + 2 apply; loss: 2 fwd + 2 bwd
        assert_eq!(g.len(), 1 + 2 + 3 + 3 + 2 + 2 + 2);
        assert!(g.is_acyclic());
        // exactly one sink cluster: apply ops
        assert!(g.sinks().len() >= 2);
    }

    #[test]
    fn bwd_links_and_flags() {
        let g = tiny_model();
        let bwd: Vec<_> = g.iter_nodes().filter(|n| n.is_backward).collect();
        assert_eq!(bwd.len(), 3 + 2 + 2); // dense bwd + apply + loss bwd
        for n in &bwd {
            if n.kind != OpKind::ApplyGrad {
                let f = n.forward_of.expect("bwd op has forward link");
                assert!(!g.node(f).is_backward);
            }
        }
    }

    #[test]
    fn colocation_constraints_present() {
        let g = tiny_model();
        let groups = g.colocation_groups();
        assert_eq!(groups.len(), 2); // one per variable
        for (_, members) in groups {
            assert_eq!(members.len(), 2); // var + apply
        }
    }

    #[test]
    fn loss_backward_reaches_first_layer() {
        let g = tiny_model();
        // every apply op is reachable from the loss fwd output
        let loss_fwd = g
            .iter_nodes()
            .find(|n| n.name == "loss/fwd1")
            .unwrap()
            .id;
        for n in g.iter_nodes().filter(|n| n.kind == OpKind::ApplyGrad) {
            assert!(g.reachable(loss_fwd, n.id), "{} unreachable", n.name);
        }
    }

    #[test]
    fn cost_model_monotone() {
        let c = CostModel::default();
        assert!(c.op_time(1e9, 0) > c.op_time(1e6, 0));
        assert!(c.op_time(0.0, 1 << 30) > c.op_time(0.0, 1 << 10));
        assert!(c.op_time(0.0, 0) >= c.launch_overhead);
    }
}
