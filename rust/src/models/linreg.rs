//! The paper's didactic graphs: the Figure-2 linear-regression working
//! example and the Figure-1 SCT-vs-m-SCT example.

use crate::graph::{MemorySpec, NodeId, OpGraph, OpKind};

/// Paper Figure 2: simplified TensorFlow graph for linear-regression
/// training with SGD. Colocation groups: {Weight, ApplyGrad} and
/// {Step, UpdateStep}. Compute costs are 1 time-unit, the Grad →
/// UpdateStep tensor costs 5 units to move (the §3.1.3 fusion example).
///
/// Units here are abstract (seconds == "time units", bytes == "memory
/// units"); pair with a `CommModel { latency: 0, bandwidth: 1.0 }` so a
/// `bytes`-unit tensor costs `bytes` time-units to transfer.
pub fn linreg_graph() -> OpGraph {
    let mut g = OpGraph::new("linreg");
    let mut add = |name: &str, kind: OpKind, compute: f64, mem: u64, out: u64| -> NodeId {
        let id = g.add_node(name, kind);
        let n = g.node_mut(id);
        n.compute = compute;
        n.mem = MemorySpec {
            params: mem,
            ..Default::default()
        };
        n.output_bytes = out;
        id
    };
    let input = add("Input", OpKind::Input, 1.0, 1, 1);
    let weight = add("Weight", OpKind::Variable, 1.0, 2, 1);
    let matmul = add("MatMul", OpKind::MatMul, 1.0, 1, 1);
    let grad = add("Grad", OpKind::MatMul, 1.0, 1, 5);
    let step = add("Step", OpKind::Variable, 1.0, 1, 1);
    let update = add("UpdateStep", OpKind::Elementwise, 1.0, 1, 1);
    let apply = add("ApplyGrad", OpKind::ApplyGrad, 1.0, 1, 1);

    g.node_mut(weight).colocation_group = Some("weight".into());
    g.node_mut(apply).colocation_group = Some("weight".into());
    g.node_mut(step).colocation_group = Some("step".into());
    g.node_mut(update).colocation_group = Some("step".into());
    g.node_mut(grad).is_backward = true;
    g.node_mut(grad).forward_of = Some(matmul);
    g.node_mut(apply).is_backward = true;

    g.add_edge(input, matmul, 1);
    g.add_edge(weight, matmul, 1);
    g.add_edge(matmul, grad, 1);
    g.add_edge(grad, update, 5); // the expensive tensor of Fig. 5
    g.add_edge(step, update, 1);
    g.add_edge(update, apply, 1);
    g.add_edge(grad, apply, 5);
    g
}

/// A Figure-1-style example graph where classical SCT (no memory limit)
/// packs more persistent state onto one device than fits in `M = 4`
/// memory units, while m-SCT succeeds with a slightly longer makespan.
///
/// Layout (compute time t, memory d in units):
///
/// ```text
///   a(1,2) ─→ b(3,2) ─→ d(2,2) ─→ f(1,1)
///     └────→ c(3,2) ─→ e(2,2) ──────┘
/// ```
///
/// One memory unit = [`FIG1_MEM_UNIT`] bytes; every edge moves 1 byte
/// (1 time-unit at unit bandwidth), so transfer buffers are the "few
/// bytes left" of the paper's §4.2 footnote rather than a whole memory
/// unit. With unlimited memory two devices suffice for makespan 8 but
/// one device would hold 3 ops (6 > 4 units); with M = 4 units the
/// placement must spread 2+2, stretching the makespan slightly.
pub const FIG1_MEM_UNIT: u64 = 100;

pub fn fig1_graph() -> OpGraph {
    let mut g = OpGraph::new("fig1");
    let mut add = |name: &str, t: f64, d: u64, out: u64| -> NodeId {
        let id = g.add_node(name, OpKind::Generic(0));
        let n = g.node_mut(id);
        n.compute = t;
        n.mem = MemorySpec {
            params: d * FIG1_MEM_UNIT,
            ..Default::default()
        };
        n.output_bytes = out;
        id
    };
    let a = add("a", 1.0, 2, 1);
    let b = add("b", 3.0, 2, 1);
    let c = add("c", 3.0, 2, 1);
    let d = add("d", 2.0, 2, 1);
    let e = add("e", 2.0, 2, 1);
    let f = add("f", 1.0, 1, 1);
    g.add_edge(a, b, 1);
    g.add_edge(a, c, 1);
    g.add_edge(b, d, 1);
    g.add_edge(c, e, 1);
    g.add_edge(d, f, 1);
    g.add_edge(e, f, 1);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linreg_matches_paper_shape() {
        let g = linreg_graph();
        assert_eq!(g.len(), 7);
        assert!(g.is_acyclic());
        let groups = g.colocation_groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups["weight"].len(), 2);
        assert_eq!(groups["step"].len(), 2);
        // the expensive grad tensor
        let grad = g.iter_nodes().find(|n| n.name == "Grad").unwrap().id;
        let update = g.iter_nodes().find(|n| n.name == "UpdateStep").unwrap().id;
        assert_eq!(g.edge_bytes(grad, update), Some(5));
    }

    #[test]
    fn fig1_structure() {
        let g = fig1_graph();
        assert_eq!(g.len(), 6);
        assert!(g.is_acyclic());
        assert_eq!(g.sources().len(), 1);
        assert_eq!(g.sinks().len(), 1);
        // total memory = 11 units; see the quickstart example for the
        // SCT-OOM vs m-SCT-succeeds reproduction on 3 × 4-unit devices.
        let total: u64 = g.iter_nodes().map(|n| n.mem.permanent_training()).sum();
        assert_eq!(total, 11 * FIG1_MEM_UNIT);
    }
}
