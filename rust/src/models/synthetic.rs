//! Synthetic scale-N graph generator for the million-op regime.
//!
//! The paper's benchmark models top out at a few thousand ops, far below
//! the scale where placement *speed* — Baechi's headline result —
//! actually differentiates algorithms. [`synthetic_graph`] emits a
//! seeded, deterministic layered DAG of any size (100K–1M ops in the
//! scaled `table3_placement_time` bench):
//!
//! * `LANES` parallel chains ("lanes") advance in lock-step layers;
//!   every op depends on its predecessor in the same lane, so most of
//!   the graph is linear chain — exactly the structure the hierarchical
//!   coarsener contracts;
//! * every `MIX_EVERY` layers an op also reads a tensor from a random
//!   other lane, bounding chain length and keeping the DAG connected
//!   enough that placement is not trivially per-lane;
//! * compute and memory are drawn from a seeded [`Pcg`], sized so a
//!   1M-op graph still fits the paper-default 4 × 8 GiB cluster.
//!
//! Determinism matters: the graph (and therefore its engine fingerprint)
//! depends only on `ops`, so bench baselines and cache keys are stable
//! across runs.

use crate::graph::{MemorySpec, OpGraph, OpKind};
use crate::util::rng::Pcg;

/// Parallel chains advancing per layer.
pub const LANES: usize = 64;
/// Cross-lane mix edge every this many layers.
pub const MIX_EVERY: usize = 24;

/// Build a deterministic `ops`-node layered DAG.
pub fn synthetic_graph(ops: usize) -> OpGraph {
    let ops = ops.max(1);
    let mut g = OpGraph::new(&format!("synthetic:{ops}"));
    let mut rng = Pcg::seed(0x5ca1ab1e ^ ops as u64);
    let lanes = LANES.min(ops);
    let mut ids = Vec::with_capacity(ops);
    for i in 0..ops {
        let lane = i % lanes;
        let step = i / lanes;
        let kind = if rng.chance(0.7) {
            OpKind::MatMul
        } else {
            OpKind::Elementwise
        };
        let id = g.add_node(&format!("syn{i}"), kind);
        {
            let node = g.node_mut(id);
            node.compute = rng.uniform(1e-5, 2e-4);
            node.mem = MemorySpec {
                params: rng.below(16 << 10) + 256,
                output: rng.below(8 << 10) + 256,
                param_grad: 0,
                upstream_grad: 0,
                temp: rng.below(4 << 10),
            };
            node.output_bytes = node.mem.output;
        }
        if step > 0 {
            let up = ids[i - lanes];
            let bytes = g.node(up).output_bytes;
            g.add_edge(up, id, bytes);
            if step % MIX_EVERY == 0 {
                let other = rng.below(lanes as u64) as usize;
                if other != lane {
                    let cross = ids[(step - 1) * lanes + other];
                    let bytes = g.node(cross).output_bytes;
                    g.add_edge(cross, id, bytes);
                }
            }
        }
        ids.push(id);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_acyclic() {
        let a = synthetic_graph(2_000);
        let b = synthetic_graph(2_000);
        assert_eq!(a.len(), 2_000);
        assert!(a.is_acyclic());
        assert_eq!(a.edge_count(), b.edge_count());
        for id in a.node_ids() {
            assert_eq!(a.node(id).compute, b.node(id).compute);
            assert_eq!(a.node(id).mem, b.node(id).mem);
        }
    }

    #[test]
    fn small_sizes_work() {
        for n in [1, 2, 63, 64, 65] {
            let g = synthetic_graph(n);
            assert_eq!(g.len(), n);
            assert!(g.is_acyclic());
        }
    }

    #[test]
    fn mostly_chains_for_the_coarsener() {
        let g = synthetic_graph(5_000);
        let chainlike = g
            .node_ids()
            .filter(|&id| g.out_degree(id) <= 1 && g.in_degree(id) <= 1)
            .count();
        assert!(
            chainlike * 2 > g.len(),
            "at least half the ops sit on plain chains ({chainlike}/{})",
            g.len()
        );
    }
}
