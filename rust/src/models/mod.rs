//! Benchmark model-graph generators (paper §5.1).
//!
//! These reproduce the *structure and cost distributions* of the paper's
//! profiled TensorFlow/PyTorch graphs — see DESIGN.md §2 for the
//! substitution rationale. Every generator emits a full training graph
//! (forward + backward + optimizer ops) with colocation constraints and
//! co-placement group annotations.

pub mod common;
pub mod gnmt;
pub mod inception;
pub mod linreg;
pub mod mlp;
pub mod synthetic;
pub mod transformer;

pub use common::calibration_probe_costs;

use crate::graph::OpGraph;

/// The paper's benchmark suite, one variant per evaluated configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Benchmark {
    /// Inception-V3 at a batch size (paper: 32, 64).
    InceptionV3 { batch: usize },
    /// GNMT at (batch, seq_len) (paper: 128/256 × 40/50).
    Gnmt { batch: usize, seq_len: usize },
    /// Transformer base at a batch size (paper: 64, 128).
    Transformer { batch: usize },
    /// The Fig. 2 linear-regression working example.
    LinReg,
    /// The e2e-trainable MLP.
    Mlp,
    /// Seeded layered scale-N graph (100K–1M ops) for the hierarchical
    /// placement bench.
    Synthetic { ops: usize },
}

impl Benchmark {
    /// Parse `inception:32`, `gnmt:128:40`, `transformer:64`, `linreg`,
    /// `mlp`, `synthetic:100000`.
    pub fn parse(s: &str) -> crate::Result<Benchmark> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize, d: usize| -> usize {
            parts.get(i).and_then(|p| p.parse().ok()).unwrap_or(d)
        };
        match parts[0] {
            "inception" => Ok(Benchmark::InceptionV3 { batch: num(1, 32) }),
            "gnmt" => Ok(Benchmark::Gnmt {
                batch: num(1, 128),
                seq_len: num(2, 40),
            }),
            "transformer" => Ok(Benchmark::Transformer { batch: num(1, 64) }),
            "linreg" => Ok(Benchmark::LinReg),
            "mlp" => Ok(Benchmark::Mlp),
            "synthetic" => Ok(Benchmark::Synthetic {
                ops: num(1, 100_000),
            }),
            other => Err(crate::BaechiError::invalid(format!(
                "unknown benchmark '{other}'"
            ))),
        }
    }

    /// Generate the training graph.
    pub fn graph(&self) -> OpGraph {
        match *self {
            Benchmark::InceptionV3 { batch } => inception::inception_v3(batch),
            Benchmark::Gnmt { batch, seq_len } => {
                gnmt::gnmt(gnmt::GnmtConfig::paper(batch, seq_len))
            }
            Benchmark::Transformer { batch } => {
                transformer::transformer(transformer::TransformerConfig::paper(batch))
            }
            Benchmark::LinReg => linreg::linreg_graph(),
            Benchmark::Mlp => mlp::mlp(&mlp::MlpConfig::default()),
            Benchmark::Synthetic { ops } => synthetic::synthetic_graph(ops),
        }
    }

    pub fn name(&self) -> String {
        match *self {
            Benchmark::InceptionV3 { batch } => format!("inception:{batch}"),
            Benchmark::Gnmt { batch, seq_len } => format!("gnmt:{batch}:{seq_len}"),
            Benchmark::Transformer { batch } => format!("transformer:{batch}"),
            Benchmark::LinReg => "linreg".to_string(),
            Benchmark::Mlp => "mlp".to_string(),
            Benchmark::Synthetic { ops } => format!("synthetic:{ops}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "inception:32",
            "gnmt:128:40",
            "transformer:64",
            "linreg",
            "mlp",
            "synthetic:1000",
        ] {
            let b = Benchmark::parse(s).unwrap();
            assert_eq!(b.name(), s);
        }
        assert!(Benchmark::parse("bogus").is_err());
    }

    #[test]
    fn all_graphs_acyclic() {
        for b in [
            Benchmark::Transformer { batch: 64 },
            Benchmark::LinReg,
            Benchmark::Mlp,
            Benchmark::Synthetic { ops: 1_000 },
        ] {
            assert!(b.graph().is_acyclic(), "{}", b.name());
        }
    }
}
