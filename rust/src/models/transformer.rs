//! Transformer (base) benchmark graph (paper §5.1, PyTorch side).
//!
//! Matches Baechi-PY's module granularity: attention is "one large matrix
//! multiplication and hence a single module" [23], layers are atomic
//! modules, so the graph is small (placement in 1–3 s, Table 3). Encoder
//! and decoder embeddings are independent until the cross-attention,
//! which is the parallelism m-ETF/m-SCT exploit in Table 4.

use super::common::{bytes_f32, matmul_flops, CostModel, ModelBuilder, ModuleSpec};
use crate::graph::{OpGraph, OpKind};

/// Configuration mirroring the paper's base Transformer.
#[derive(Debug, Clone, Copy)]
pub struct TransformerConfig {
    pub batch: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub heads: usize,
    pub enc_layers: usize,
    pub dec_layers: usize,
    pub vocab: usize,
}

impl TransformerConfig {
    pub fn paper(batch: usize) -> TransformerConfig {
        TransformerConfig {
            batch,
            seq_len: 50,
            d_model: 512,
            d_ff: 2048,
            heads: 8,
            enc_layers: 6,
            dec_layers: 6,
            vocab: 30_000,
        }
    }
}

fn mha(
    b: &mut ModelBuilder,
    name: &str,
    cfg: &TransformerConfig,
    deps: &[usize],
) -> usize {
    let (bs, l, d) = (cfg.batch, cfg.seq_len, cfg.d_model);
    // QKV projections + attention matmuls + output projection.
    let flops = 4.0 * matmul_flops(bs * l, d, d) + 2.0 * matmul_flops(bs * l, d, l);
    let params = 4 * bytes_f32(&[d, d]);
    let output = bytes_f32(&[bs, l, d]);
    let temp = bytes_f32(&[bs, cfg.heads, l, l]) + 3 * output;
    b.add_module(
        ModuleSpec::new(name, OpKind::Attention)
            .micro(4) // qkv, scores, softmax·V, out-proj (PyTorch modules)
            .vars(2)
            .flops(flops)
            .params(params)
            .output(output)
            .temp(temp),
        deps,
    )
}

fn ffn(b: &mut ModelBuilder, name: &str, cfg: &TransformerConfig, deps: &[usize]) -> usize {
    let (bs, l, d, f) = (cfg.batch, cfg.seq_len, cfg.d_model, cfg.d_ff);
    let flops = matmul_flops(bs * l, d, f) + matmul_flops(bs * l, f, d);
    let params = bytes_f32(&[d, f]) + bytes_f32(&[f, d]);
    let output = bytes_f32(&[bs, l, d]);
    let temp = bytes_f32(&[bs, l, f]);
    b.add_module(
        ModuleSpec::new(name, OpKind::MatMul)
            .micro(3)
            .vars(2)
            .flops(flops)
            .params(params)
            .output(output)
            .temp(temp),
        deps,
    )
}

fn layer_norm(b: &mut ModelBuilder, name: &str, cfg: &TransformerConfig, deps: &[usize]) -> usize {
    let output = bytes_f32(&[cfg.batch, cfg.seq_len, cfg.d_model]);
    b.add_module(
        ModuleSpec::new(name, OpKind::Elementwise)
            .micro(2)
            .vars(1)
            .flops(output as f64)
            .params(bytes_f32(&[2 * cfg.d_model]))
            .output(output)
            .temp(output / 2),
        deps,
    )
}

/// Build the Transformer training graph.
pub fn transformer(cfg: TransformerConfig) -> OpGraph {
    let (bs, l, d) = (cfg.batch, cfg.seq_len, cfg.d_model);
    let mut b = ModelBuilder::new(&format!("transformer_bs{bs}_len{l}"), CostModel::default());

    let src = b.add_input("src_tokens", bytes_f32(&[bs, l]));
    let tgt = b.add_input("tgt_tokens", bytes_f32(&[bs, l]));

    let emb = |b: &mut ModelBuilder, name: &str, dep: usize| {
        b.add_module(
            ModuleSpec::new(name, OpKind::Embedding)
                .micro(2)
                .vars(1)
                .flops((bs * l * d) as f64)
                .params(bytes_f32(&[cfg.vocab, d]))
                .output(bytes_f32(&[bs, l, d]))
                .temp(0),
            &[dep],
        )
    };
    let enc_emb = emb(&mut b, "enc_embed", src);
    let dec_emb = emb(&mut b, "dec_embed", tgt);

    // Encoder stack.
    let mut e = enc_emb;
    for i in 0..cfg.enc_layers {
        let a = mha(&mut b, &format!("enc{i}/self_attn"), &cfg, &[e]);
        let n1 = layer_norm(&mut b, &format!("enc{i}/ln1"), &cfg, &[a]);
        let f = ffn(&mut b, &format!("enc{i}/ffn"), &cfg, &[n1]);
        e = layer_norm(&mut b, &format!("enc{i}/ln2"), &cfg, &[f]);
    }
    let enc_out = e;

    // Decoder stack with cross-attention on the encoder output.
    let mut dcur = dec_emb;
    for i in 0..cfg.dec_layers {
        let sa = mha(&mut b, &format!("dec{i}/self_attn"), &cfg, &[dcur]);
        let n1 = layer_norm(&mut b, &format!("dec{i}/ln1"), &cfg, &[sa]);
        let ca = mha(&mut b, &format!("dec{i}/cross_attn"), &cfg, &[n1, enc_out]);
        let n2 = layer_norm(&mut b, &format!("dec{i}/ln2"), &cfg, &[ca]);
        let f = ffn(&mut b, &format!("dec{i}/ffn"), &cfg, &[n2]);
        dcur = layer_norm(&mut b, &format!("dec{i}/ln3"), &cfg, &[f]);
    }

    // Generator: projection to vocab + loss.
    let proj = b.add_module(
        ModuleSpec::new("generator", OpKind::MatMul)
            .micro(2)
            .vars(1)
            .flops(matmul_flops(bs * l, d, cfg.vocab))
            .params(bytes_f32(&[d, cfg.vocab]))
            .output(bytes_f32(&[bs, l, cfg.vocab]))
            .temp(bytes_f32(&[bs, l, cfg.vocab])),
        &[dcur],
    );
    // Softmax probabilities are retained for backward (as in GNMT).
    let loss = b.add_module(
        ModuleSpec::new("loss", OpKind::Loss)
            .micro(2)
            .flops((bs * l * cfg.vocab) as f64 * 4.0)
            .output(bytes_f32(&[bs, l, cfg.vocab]))
            .temp(3 * bytes_f32(&[bs, l, cfg.vocab])),
        &[proj],
    );
    b.build_training_graph(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_granularity_is_coarse() {
        let g = transformer(TransformerConfig::paper(64));
        assert!(g.is_acyclic());
        // Baechi-PY module graphs are small: hundreds of micro-ops here.
        assert!(g.len() < 2_000, "ops = {}", g.len());
        assert!(g.len() > 100, "ops = {}", g.len());
    }

    #[test]
    fn encoder_decoder_parallelism_exists() {
        // The encoder chain and the decoder-embedding + self-attention
        // prefix must be independent (no path between them).
        let g = transformer(TransformerConfig::paper(64));
        let enc0 = g
            .iter_nodes()
            .find(|n| n.name.starts_with("enc0/self_attn/fwd"))
            .unwrap()
            .id;
        let dec_sa = g
            .iter_nodes()
            .find(|n| n.name.starts_with("dec0/self_attn/fwd"))
            .unwrap()
            .id;
        assert!(!g.reachable(enc0, dec_sa));
        assert!(!g.reachable(dec_sa, enc0));
    }

    #[test]
    fn cross_attention_joins_streams() {
        let g = transformer(TransformerConfig::paper(64));
        let enc_last_ln = g
            .iter_nodes()
            .find(|n| n.name.starts_with("enc5/ln2/fwd1"))
            .unwrap()
            .id;
        let cross = g
            .iter_nodes()
            .find(|n| n.name.starts_with("dec0/cross_attn/fwd0"))
            .unwrap()
            .id;
        assert!(g.reachable(enc_last_ln, cross));
    }

    #[test]
    fn memory_scales_with_batch() {
        let g64 = transformer(TransformerConfig::paper(64));
        let g128 = transformer(TransformerConfig::paper(128));
        assert!(g128.total_permanent_memory() > g64.total_permanent_memory());
    }
}
