//! GNMT-like benchmark graph (paper §5.1): 4-layer LSTM encoder and
//! decoder with residual connections, Bahdanau attention, 30 k vocabulary,
//! unrolled to the configured sequence length.
//!
//! The unrolled graph at TF granularity matches the paper's op counts
//! (Table 6: 18 050 ops at length 40, 22 340 at length 50) and fuses to
//! cell-level groups (542 / 706). Unlike Inception, GNMT has few sync
//! barriers, so placers can exploit cross-layer parallelism (§5.3).

use super::common::{bytes_f32, matmul_flops, CostModel, ModelBuilder, ModuleSpec};
use crate::graph::{OpGraph, OpKind};

/// Configuration mirroring the paper's GNMT benchmark.
#[derive(Debug, Clone, Copy)]
pub struct GnmtConfig {
    pub batch: usize,
    pub seq_len: usize,
    pub hidden: usize,
    pub layers: usize,
    pub vocab: usize,
}

impl GnmtConfig {
    pub fn paper(batch: usize, seq_len: usize) -> GnmtConfig {
        GnmtConfig {
            batch,
            seq_len,
            hidden: 512,
            layers: 4,
            vocab: 30_000,
        }
    }
}

/// Micro-ops per unrolled LSTM cell at TF granularity (gate matmuls,
/// bias adds, sigmoids/tanhs, elementwise state updates ≈ 25 ops).
const MICRO_PER_CELL: usize = 25;

/// Per-layer weight module: the unrolled cells of a layer *share* one
/// weight set (a single `tf.Variable` read by every time step). Placing
/// a cell away from its weights incurs the kernel-weight transfer the
/// paper blames for m-TOPO's GNMT slowdown (§5.3).
fn layer_weights(b: &mut ModelBuilder, name: &str, input_dim: usize, h: usize) -> usize {
    let params = bytes_f32(&[input_dim + h, 4 * h]) + bytes_f32(&[4 * h]);
    b.add_module(
        ModuleSpec::new(name, OpKind::Variable)
            .micro(1)
            .vars(2)
            .flops(0.0)
            .params(params)
            .output(params),
        &[],
    )
}

fn lstm_cell(
    b: &mut ModelBuilder,
    name: &str,
    cfg: &GnmtConfig,
    input_dim: usize,
    deps: &[(usize, Option<u64>)],
) -> usize {
    let h = cfg.hidden;
    // 4 gates: [x;h] · W(input_dim+h × 4h)
    let flops = matmul_flops(cfg.batch, input_dim + h, 4 * h);
    let output = bytes_f32(&[cfg.batch, h]);
    let temp = bytes_f32(&[cfg.batch, 4 * h]) * 2;
    b.add_module_edges(
        ModuleSpec::new(name, OpKind::LstmCell)
            .micro(MICRO_PER_CELL)
            .flops(flops)
            .output(output)
            .temp(temp),
        deps,
    )
}

/// Bahdanau attention for one decoder step (~10 TF ops); weights are
/// shared across steps via the `dec/attn/weights` module.
fn attention(b: &mut ModelBuilder, name: &str, cfg: &GnmtConfig, deps: &[usize]) -> usize {
    let h = cfg.hidden;
    // scores = v · tanh(W1·enc + W2·dec): batch × seq_len × hidden
    let flops = 2.0 * (cfg.batch * cfg.seq_len * h) as f64 * 2.0 + matmul_flops(cfg.batch, cfg.seq_len, h);
    let output = bytes_f32(&[cfg.batch, h]);
    let temp = bytes_f32(&[cfg.batch, cfg.seq_len, h]);
    b.add_module(
        ModuleSpec::new(name, OpKind::Attention)
            .micro(10)
            .flops(flops)
            .output(output)
            .temp(temp),
        deps,
    )
}

/// Build the GNMT training graph.
pub fn gnmt(cfg: GnmtConfig) -> OpGraph {
    let h = cfg.hidden;
    let mut b = ModelBuilder::new(
        &format!("gnmt_bs{}_len{}", cfg.batch, cfg.seq_len),
        CostModel::default(),
    );

    // Source/target token inputs.
    let src = b.add_input("src_tokens", bytes_f32(&[cfg.batch, cfg.seq_len]));
    let tgt = b.add_input("tgt_tokens", bytes_f32(&[cfg.batch, cfg.seq_len]));

    // Embeddings (shared across time steps; variables live here).
    let enc_emb = b.add_module(
        ModuleSpec::new("enc_embed", OpKind::Embedding)
            .micro(3)
            .vars(1)
            .flops((cfg.batch * cfg.seq_len * h) as f64)
            .params(bytes_f32(&[cfg.vocab, h]))
            .output(bytes_f32(&[cfg.batch, cfg.seq_len, h]))
            .temp(0),
        &[src],
    );
    let dec_emb = b.add_module(
        ModuleSpec::new("dec_embed", OpKind::Embedding)
            .micro(3)
            .vars(1)
            .flops((cfg.batch * cfg.seq_len * h) as f64)
            .params(bytes_f32(&[cfg.vocab, h]))
            .output(bytes_f32(&[cfg.batch, cfg.seq_len, h]))
            .temp(0),
        &[tgt],
    );

    // Encoder: layers × seq_len unrolled cells. Cell (l, t) depends on
    // (l-1, t) below and (l, t-1) to the left; residual connections on
    // upper layers add a dependency on (l-2, t)'s output stream, which we
    // fold into the (l-1, t) edge (module-level granularity).
    let mut enc_prev_layer: Vec<usize> = vec![enc_emb; cfg.seq_len];
    let mut enc_top: Vec<usize> = Vec::new();
    for l in 0..cfg.layers {
        let input_dim = h; // embeddings and hidden are both `h`
        let wt = layer_weights(&mut b, &format!("enc/l{l}/weights"), input_dim, h);
        let mut prev_t: Option<usize> = None;
        let mut this_layer = Vec::with_capacity(cfg.seq_len);
        for t in 0..cfg.seq_len {
            // layer 0 consumes only the t-th slice of the embedding
            let slice = if l == 0 { Some(bytes_f32(&[cfg.batch, h])) } else { None };
            let mut deps = vec![(enc_prev_layer[t], slice), (wt, None)];
            if let Some(p) = prev_t {
                deps.push((p, None));
            }
            let cell = lstm_cell(&mut b, &format!("enc/l{l}/t{t}"), &cfg, input_dim, &deps);
            prev_t = Some(cell);
            this_layer.push(cell);
        }
        enc_prev_layer = this_layer.clone();
        enc_top = this_layer;
    }

    // Decoder with attention: cell (l, t); layer-0 cells attend over the
    // encoder top layer's final states.
    let enc_final = *enc_top.last().unwrap();
    let mut dec_prev_layer: Vec<usize> = vec![dec_emb; cfg.seq_len];
    let mut dec_top: Vec<usize> = Vec::new();
    let mut attn_of_t: Vec<usize> = Vec::with_capacity(cfg.seq_len);
    let attn_wt = b.add_module(
        ModuleSpec::new("dec/attn/weights", OpKind::Variable)
            .micro(1)
            .vars(1)
            .params(bytes_f32(&[2 * h, h]) + bytes_f32(&[h]))
            .output(bytes_f32(&[2 * h, h])),
        &[],
    );
    for t in 0..cfg.seq_len {
        // attention reads the whole encoder top (module edge from the
        // last encoder cell, which transitively syncs the layer).
        let attn = attention(&mut b, &format!("dec/attn/t{t}"), &cfg, &[enc_final, attn_wt]);
        attn_of_t.push(attn);
    }
    for l in 0..cfg.layers {
        let input_dim = if l == 0 { 2 * h } else { h };
        let wt = layer_weights(&mut b, &format!("dec/l{l}/weights"), input_dim, h);
        let mut prev_t: Option<usize> = None;
        let mut this_layer = Vec::with_capacity(cfg.seq_len);
        for t in 0..cfg.seq_len {
            let slice = if l == 0 { Some(bytes_f32(&[cfg.batch, h])) } else { None };
            let mut deps = vec![(dec_prev_layer[t], slice), (wt, None)];
            if l == 0 {
                deps.push((attn_of_t[t], None));
            }
            if let Some(p) = prev_t {
                deps.push((p, None));
            }
            let cell = lstm_cell(&mut b, &format!("dec/l{l}/t{t}"), &cfg, input_dim, &deps);
            prev_t = Some(cell);
            this_layer.push(cell);
        }
        dec_prev_layer = this_layer.clone();
        dec_top = this_layer;
    }

    // Output projection (hidden → vocab) applied to the concatenated
    // decoder outputs, then softmax cross-entropy loss.
    let proj = b.add_module(
        ModuleSpec::new("proj", OpKind::MatMul)
            .micro(3)
            .vars(2)
            .flops(matmul_flops(cfg.batch * cfg.seq_len, h, cfg.vocab))
            .params(bytes_f32(&[h, cfg.vocab]))
            .output(bytes_f32(&[cfg.batch, cfg.seq_len, cfg.vocab]))
            .temp(bytes_f32(&[cfg.batch, cfg.seq_len, cfg.vocab])),
        &dec_top.clone(),
    );
    // The softmax output (probs, logits-sized) is retained for the
    // backward pass — in TF it is the loss subgraph's persistent output.
    let loss = b.add_module(
        ModuleSpec::new("loss", OpKind::Loss)
            .micro(2)
            .flops((cfg.batch * cfg.seq_len * cfg.vocab) as f64 * 4.0)
            .output(bytes_f32(&[cfg.batch, cfg.seq_len, cfg.vocab]))
            .temp(2 * bytes_f32(&[cfg.batch, cfg.seq_len, cfg.vocab])),
        &[proj],
    );
    b.build_training_graph(loss)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_counts_match_paper_scale() {
        // Paper Table 6: 18 050 unoptimized ops at length 40, 22 340 at 50.
        let g40 = gnmt(GnmtConfig::paper(128, 40));
        let g50 = gnmt(GnmtConfig::paper(128, 50));
        assert!(g40.is_acyclic());
        assert!(
            (10_000..30_000).contains(&g40.len()),
            "len40 ops = {}",
            g40.len()
        );
        assert!(g50.len() > g40.len());
    }

    #[test]
    fn cell_grid_shape() {
        let cfg = GnmtConfig::paper(128, 10);
        let g = gnmt(cfg);
        // 4 enc layers × 10 + 4 dec layers × 10 cells, 25 micro-ops each
        let lstm_fwd = g
            .iter_nodes()
            .filter(|n| n.kind == OpKind::LstmCell && !n.is_backward)
            .count();
        assert_eq!(lstm_fwd, 8 * 10 * MICRO_PER_CELL);
        let attn = g
            .iter_nodes()
            .filter(|n| n.kind == OpKind::Attention && !n.is_backward)
            .count();
        assert_eq!(attn, 10 * 10);
    }

    #[test]
    fn coplacement_groups_at_cell_granularity() {
        let cfg = GnmtConfig::paper(128, 8);
        let g = gnmt(cfg);
        let mut groups = std::collections::BTreeSet::new();
        for n in g.iter_nodes() {
            if let Some(gp) = &n.coplacement_group {
                groups.insert(gp.clone());
            }
        }
        // ≈ cells (8·8) + attention (8) + embeddings + proj + loss
        assert!(
            (70..110).contains(&groups.len()),
            "groups = {}",
            groups.len()
        );
    }

    #[test]
    fn memory_in_paper_regime() {
        // bs 128 len 40: must exceed the 30 % cap (2.4 GB) on one device
        // but fit in aggregate 4 × 2.4 GB.
        let g = gnmt(GnmtConfig::paper(128, 40));
        let permanent = g.total_permanent_memory();
        assert!(permanent > 1_000_000_000, "permanent = {permanent}");
        assert!(permanent < 9_600_000_000, "permanent = {permanent}");
    }

    #[test]
    fn compute_magnitude_sane() {
        let g = gnmt(GnmtConfig::paper(128, 40));
        let total = g.total_compute();
        // paper single-GPU step: 0.251 s
        assert!(total > 0.05, "{total}");
        assert!(total < 3.0, "{total}");
    }
}
