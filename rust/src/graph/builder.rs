//! Fluent construction helpers for operator graphs.
//!
//! The model generators in [`crate::models`] use this to declare layers
//! succinctly; tests use it to sketch small DAGs.

use super::{MemorySpec, NodeId, OpGraph, OpKind};

/// Builder wrapper holding defaults for batch construction.
pub struct GraphBuilder {
    pub graph: OpGraph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            graph: OpGraph::new(name),
        }
    }

    /// Start configuring a node.
    pub fn op(&mut self, name: &str, kind: OpKind) -> NodeCfg<'_> {
        let id = self.graph.add_node(name, kind);
        NodeCfg { b: self, id }
    }

    /// Connect `src → dst` with the source's recorded output bytes.
    pub fn wire(&mut self, src: NodeId, dst: NodeId) {
        let bytes = self.graph.node(src).output_bytes;
        self.graph.add_edge(src, dst, bytes);
    }

    /// Connect a chain of nodes head-to-tail.
    pub fn chain(&mut self, nodes: &[NodeId]) {
        for w in nodes.windows(2) {
            self.wire(w[0], w[1]);
        }
    }

    pub fn finish(self) -> OpGraph {
        debug_assert!(self.graph.is_acyclic(), "builder produced a cycle");
        self.graph
    }
}

/// In-progress node configuration.
pub struct NodeCfg<'a> {
    b: &'a mut GraphBuilder,
    id: NodeId,
}

impl<'a> NodeCfg<'a> {
    pub fn compute(self, secs: f64) -> Self {
        self.b.graph.node_mut(self.id).compute = secs;
        self
    }

    pub fn mem(self, mem: MemorySpec) -> Self {
        self.b.graph.node_mut(self.id).mem = mem;
        self
    }

    /// Set params+grad memory and scratch in one call (common case).
    pub fn mem_simple(self, params: u64, output: u64, temp: u64) -> Self {
        let n = self.b.graph.node_mut(self.id);
        n.mem = MemorySpec {
            params,
            output,
            param_grad: params,
            upstream_grad: output,
            temp,
        };
        n.output_bytes = output;
        self
    }

    pub fn output_bytes(self, bytes: u64) -> Self {
        let n = self.b.graph.node_mut(self.id);
        n.output_bytes = bytes;
        n.mem.output = bytes;
        self
    }

    pub fn colocate(self, group: &str) -> Self {
        self.b.graph.node_mut(self.id).colocation_group = Some(group.to_string());
        self
    }

    pub fn coplace(self, group: &str) -> Self {
        self.b.graph.node_mut(self.id).coplacement_group = Some(group.to_string());
        self
    }

    pub fn backward_of(self, fwd: NodeId) -> Self {
        let n = self.b.graph.node_mut(self.id);
        n.is_backward = true;
        n.forward_of = Some(fwd);
        self
    }

    /// Add incoming edges from the given nodes (each with its output size).
    pub fn after(self, preds: &[NodeId]) -> Self {
        for &p in preds {
            self.b.wire(p, self.id);
        }
        self
    }

    pub fn id(self) -> NodeId {
        self.id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_chain() {
        let mut b = GraphBuilder::new("t");
        let x = b.op("x", OpKind::Input).output_bytes(64).id();
        let l1 = b
            .op("l1", OpKind::MatMul)
            .compute(1e-3)
            .mem_simple(1024, 128, 64)
            .after(&[x])
            .id();
        let l2 = b
            .op("l2", OpKind::MatMul)
            .compute(2e-3)
            .mem_simple(2048, 128, 64)
            .after(&[l1])
            .id();
        let g = b.finish();
        assert_eq!(g.len(), 3);
        assert_eq!(g.edge_bytes(x, l1), Some(64));
        assert_eq!(g.edge_bytes(l1, l2), Some(128));
        assert!((g.total_compute() - 3e-3).abs() < 1e-12);
        assert_eq!(g.node(l1).mem.param_grad, 1024);
    }

    #[test]
    fn backward_links() {
        let mut b = GraphBuilder::new("t");
        let f = b.op("fwd", OpKind::MatMul).output_bytes(8).id();
        let w = b.op("bwd", OpKind::MatMul).backward_of(f).after(&[f]).id();
        let g = b.finish();
        assert!(g.node(w).is_backward);
        assert_eq!(g.node(w).forward_of, Some(f));
    }
}
