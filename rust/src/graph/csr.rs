//! Compressed-sparse-row adjacency snapshot of an [`OpGraph`].
//!
//! `OpGraph` stores adjacency as one `Vec` per node — convenient while a
//! graph is being built or mutated, but at 100K–1M ops the per-node
//! allocations and pointer chasing dominate traversal-heavy passes (the
//! hierarchical coarsener re-scans every edge once per round). [`Csr`]
//! flattens both directions into four arrays built in two O(V + E)
//! passes, so a full edge sweep is a linear walk over contiguous memory.
//!
//! The snapshot is indexed by raw `NodeId` slots (`graph.capacity()`),
//! so tombstoned nodes simply have empty adjacency — the same convention
//! the rest of the codebase uses for dense side tables.

use super::{NodeId, OpGraph};

/// Immutable CSR view of a graph's adjacency (both directions).
#[derive(Debug, Clone)]
pub struct Csr {
    out_off: Vec<usize>,
    out_adj: Vec<(NodeId, u64)>,
    in_off: Vec<usize>,
    in_adj: Vec<(NodeId, u64)>,
}

impl Csr {
    /// Snapshot `graph`'s live adjacency.
    pub fn build(graph: &OpGraph) -> Csr {
        let cap = graph.capacity();
        let mut out_off = Vec::with_capacity(cap + 1);
        let mut in_off = Vec::with_capacity(cap + 1);
        out_off.push(0);
        in_off.push(0);
        let mut n_edges = 0usize;
        for slot in 0..cap {
            let id = NodeId(slot);
            if graph.is_alive(id) {
                n_edges += graph.out_degree(id);
            }
            out_off.push(n_edges);
            // in_off filled in the second pass below.
        }
        let mut out_adj = Vec::with_capacity(n_edges);
        let mut in_count = vec![0usize; cap];
        for slot in 0..cap {
            let id = NodeId(slot);
            if graph.is_alive(id) {
                out_adj.extend_from_slice(graph.successors(id));
                in_count[slot] = graph.in_degree(id);
            }
        }
        let mut total = 0usize;
        for &c in &in_count {
            total += c;
            in_off.push(total);
        }
        let mut in_adj = Vec::with_capacity(total);
        for slot in 0..cap {
            let id = NodeId(slot);
            if graph.is_alive(id) {
                in_adj.extend_from_slice(graph.predecessors(id));
            }
        }
        Csr {
            out_off,
            out_adj,
            in_off,
            in_adj,
        }
    }

    /// Number of node slots (== `graph.capacity()` at build time).
    pub fn n(&self) -> usize {
        self.out_off.len() - 1
    }

    /// Total directed edges in the snapshot.
    pub fn edge_count(&self) -> usize {
        self.out_adj.len()
    }

    /// Successors of `u` with edge bytes.
    pub fn out(&self, u: NodeId) -> &[(NodeId, u64)] {
        &self.out_adj[self.out_off[u.0]..self.out_off[u.0 + 1]]
    }

    /// Predecessors of `u` with edge bytes.
    pub fn ins(&self, u: NodeId) -> &[(NodeId, u64)] {
        &self.in_adj[self.in_off[u.0]..self.in_off[u.0 + 1]]
    }

    pub fn out_degree(&self, u: NodeId) -> usize {
        self.out_off[u.0 + 1] - self.out_off[u.0]
    }

    pub fn in_degree(&self, u: NodeId) -> usize {
        self.in_off[u.0 + 1] - self.in_off[u.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn diamond() -> (OpGraph, [NodeId; 4]) {
        let mut g = OpGraph::new("diamond");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        g.add_edge(a, b, 10);
        g.add_edge(a, c, 11);
        g.add_edge(b, d, 20);
        g.add_edge(c, d, 21);
        (g, [a, b, c, d])
    }

    #[test]
    fn csr_matches_vec_adjacency() {
        let (g, _) = diamond();
        let csr = Csr::build(&g);
        assert_eq!(csr.n(), g.capacity());
        assert_eq!(csr.edge_count(), g.edge_count());
        for id in g.node_ids() {
            assert_eq!(csr.out(id), g.successors(id));
            assert_eq!(csr.ins(id), g.predecessors(id));
            assert_eq!(csr.out_degree(id), g.out_degree(id));
            assert_eq!(csr.in_degree(id), g.in_degree(id));
        }
    }

    #[test]
    fn csr_skips_tombstoned_nodes() {
        let (mut g, [a, b, _c, d]) = diamond();
        g.remove_node(b);
        let csr = Csr::build(&g);
        assert_eq!(csr.out_degree(b), 0);
        assert_eq!(csr.in_degree(b), 0);
        assert_eq!(csr.out(b), &[]);
        assert_eq!(csr.out(a), g.successors(a));
        assert_eq!(csr.ins(d), g.predecessors(d));
        assert_eq!(csr.edge_count(), g.edge_count());
    }
}
