//! Graph deltas for incremental placement.
//!
//! Two versions of a model graph (a layer tweaked, a tensor grown, an op
//! spliced in) usually share almost all of their structure. The serving
//! layer diffs them by per-op **cone fingerprints**
//! ([`crate::engine::fingerprint::cone_fingerprints`]): an op whose name,
//! attributes, and entire ancestor cone are unchanged is *clean* and can
//! keep its cached device; everything else is *dirty* and gets re-placed.
//!
//! Also home to the deterministic mutation model
//! ([`MutationSpec`] / [`mutate`]) that the serving benches, stress tests,
//! and property tests use to generate realistic small-delta request
//! streams.

use crate::graph::{NodeId, OpGraph, OpKind};
use crate::util::rng::Pcg;
use std::collections::BTreeMap;

/// The diff between two graph versions, from the new graph's viewpoint.
#[derive(Debug, Clone)]
pub struct GraphDelta {
    /// New-graph nodes whose cone changed (or that have no clean match).
    pub dirty: Vec<NodeId>,
    /// `(new_id, old_id)` pairs with identical names and cone hashes.
    pub clean: Vec<(NodeId, NodeId)>,
    /// `dirty / (dirty + clean)`; 0 for identical graphs.
    pub dirty_fraction: f64,
}

/// Diff `new` against `old` using precomputed cone fingerprints (indexed
/// by id slot, as returned by `cone_fingerprints`). Matching is by op
/// *name*: a new-graph op is clean iff exactly one old op carries its name
/// and their cone hashes agree. Ops with duplicated names are
/// conservatively dirty.
pub fn diff_by_cones(
    old: &OpGraph,
    new: &OpGraph,
    old_cones: &[u64],
    new_cones: &[u64],
) -> GraphDelta {
    let mut by_name: BTreeMap<&str, Option<(NodeId, u64)>> = BTreeMap::new();
    for n in old.iter_nodes() {
        by_name
            .entry(n.name.as_str())
            .and_modify(|e| *e = None) // ambiguous name → never clean
            .or_insert(Some((n.id, old_cones[n.id.0])));
    }
    let mut dirty = Vec::new();
    let mut clean = Vec::new();
    for n in new.iter_nodes() {
        match by_name.get(n.name.as_str()) {
            Some(Some((old_id, old_cone))) if *old_cone == new_cones[n.id.0] => {
                clean.push((n.id, *old_id));
            }
            _ => dirty.push(n.id),
        }
    }
    let total = (dirty.len() + clean.len()).max(1);
    GraphDelta {
        dirty_fraction: dirty.len() as f64 / total as f64,
        dirty,
        clean,
    }
}

/// Knobs for [`mutate`]: how much one call perturbs the graph.
#[derive(Debug, Clone)]
pub struct MutationSpec {
    /// Point mutations applied per call (≥ 1).
    pub ops: usize,
    /// Relative ± jitter on a mutated op's compute cost.
    pub compute_jitter: f64,
    /// Max relative growth of a mutated edge's payload (edges only ever
    /// grow: `add_edge` merges duplicates by max).
    pub bytes_growth: f64,
    /// Probability a mutation splices a new op into the graph instead of
    /// perturbing an existing one.
    pub p_add_node: f64,
}

impl MutationSpec {
    /// A "small delta": the serving scenario of a model iterated on by a
    /// user — one tweak per request.
    pub fn small() -> MutationSpec {
        MutationSpec {
            ops: 1,
            compute_jitter: 0.05,
            bytes_growth: 0.10,
            p_add_node: 0.15,
        }
    }
}

impl Default for MutationSpec {
    fn default() -> MutationSpec {
        MutationSpec::small()
    }
}

/// Apply `spec.ops` random point mutations to `g` in place. Mutations
/// preserve acyclicity, node-name uniqueness (new ops are named
/// `mut<slot>`), and the graph's `name` (version streams stay keyed to
/// the same logical model). Deterministic for a fixed RNG state.
pub fn mutate(g: &mut OpGraph, rng: &mut Pcg, spec: &MutationSpec) {
    for _ in 0..spec.ops.max(1) {
        let ids: Vec<NodeId> = g.node_ids().collect();
        if ids.is_empty() {
            return;
        }
        if rng.chance(spec.p_add_node) {
            // Splice a cheap elementwise op under a random producer; feed
            // one of the producer's existing consumers when it has any so
            // the new op lands on a real dataflow path. `src → new` and
            // `new → (successor of src)` cannot close a cycle: the new
            // node has no other edges.
            let src = *rng.choose(&ids);
            let name = format!("mut{}", g.capacity());
            let (compute, bytes) = {
                let s = g.node(src);
                ((s.compute * 0.1).max(1e-6), s.output_bytes.max(1))
            };
            let id = g.add_node(&name, OpKind::Elementwise);
            {
                let n = g.node_mut(id);
                n.compute = compute;
                n.mem.output = bytes;
                n.mem.temp = bytes;
                n.output_bytes = bytes;
            }
            let consumers: Vec<NodeId> = g
                .successors(src)
                .iter()
                .map(|&(d, _)| d)
                .filter(|&d| d != id)
                .collect();
            g.add_edge(src, id, bytes);
            if !consumers.is_empty() {
                let dst = *rng.choose(&consumers);
                g.add_edge(id, dst, bytes);
            }
        } else if rng.chance(0.5) {
            // Jitter one op's compute cost.
            let id = *rng.choose(&ids);
            let f = 1.0 + rng.uniform(-spec.compute_jitter, spec.compute_jitter);
            let n = g.node_mut(id);
            n.compute = (n.compute * f).max(1e-9);
        } else {
            // Grow one edge's payload (a tensor got bigger).
            let with_out: Vec<NodeId> = ids
                .iter()
                .copied()
                .filter(|&i| g.out_degree(i) > 0)
                .collect();
            if with_out.is_empty() {
                continue;
            }
            let src = *rng.choose(&with_out);
            let outs: Vec<(NodeId, u64)> = g.successors(src).to_vec();
            let &(dst, bytes) = rng.choose(&outs);
            let grown = bytes + 1 + (bytes as f64 * rng.uniform(0.0, spec.bytes_growth)) as u64;
            g.add_edge(src, dst, grown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::fingerprint::cone_fingerprints;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev: Option<NodeId> = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0 + i as f64;
            g.node_mut(id).output_bytes = 100;
            g.node_mut(id).mem.output = 100;
            if let Some(p) = prev {
                g.add_edge(p, id, 100);
            }
            prev = Some(id);
        }
        g
    }

    #[test]
    fn identical_graphs_diff_all_clean() {
        let g = chain(6);
        let cones = cone_fingerprints(&g).unwrap();
        let d = diff_by_cones(&g, &g.clone(), &cones, &cones);
        assert!(d.dirty.is_empty());
        assert_eq!(d.clean.len(), 6);
        assert_eq!(d.dirty_fraction, 0.0);
    }

    #[test]
    fn tail_mutation_dirties_only_the_tail() {
        let g = chain(6);
        let old = cone_fingerprints(&g).unwrap();
        let mut m = g.clone();
        let last = m.node_ids().last().unwrap();
        m.node_mut(last).compute += 1.0;
        let new = cone_fingerprints(&m).unwrap();
        let d = diff_by_cones(&g, &m, &old, &new);
        assert_eq!(d.dirty, vec![last]);
        assert_eq!(d.clean.len(), 5);
        assert!(d.dirty_fraction < 0.2);
    }

    #[test]
    fn duplicate_names_are_conservatively_dirty() {
        let mut old = OpGraph::new("dup");
        old.add_node("x", OpKind::MatMul);
        old.add_node("x", OpKind::MatMul);
        let mut new = OpGraph::new("dup");
        new.add_node("x", OpKind::MatMul);
        let oc = cone_fingerprints(&old).unwrap();
        let nc = cone_fingerprints(&new).unwrap();
        let d = diff_by_cones(&old, &new, &oc, &nc);
        assert_eq!(d.dirty.len(), 1);
        assert!(d.clean.is_empty());
    }

    #[test]
    fn mutate_preserves_dag_and_name_uniqueness() {
        let mut g = chain(8);
        let mut rng = Pcg::seed(0xde17a);
        let spec = MutationSpec::small();
        for _ in 0..200 {
            mutate(&mut g, &mut rng, &spec);
            assert!(g.topo_order().is_some(), "mutation broke acyclicity");
        }
        let mut names: Vec<&str> = g.iter_nodes().map(|n| n.name.as_str()).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate node names after mutation");
        assert_eq!(g.name, "chain", "graph identity must survive mutation");
        assert!(g.len() > 8, "200 rounds at p_add_node=0.15 add nodes");
    }

    #[test]
    fn mutate_is_deterministic_for_a_seed() {
        let run = || {
            let mut g = chain(8);
            let mut rng = Pcg::seed(42);
            for _ in 0..50 {
                mutate(&mut g, &mut rng, &MutationSpec::small());
            }
            crate::engine::fingerprint::graph_fingerprint(&g)
        };
        assert_eq!(run(), run());
    }
}
