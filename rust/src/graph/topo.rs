//! Topological ordering and cycle detection (Kahn's algorithm, paper §2.2).

use super::{NodeId, OpGraph};

impl OpGraph {
    /// Kahn topological order over live nodes; `None` if the graph has a
    /// cycle. Ties are broken by node id for determinism.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let cap = self.capacity();
        let mut indeg = vec![0usize; cap];
        let mut live = 0usize;
        for id in self.node_ids() {
            live += 1;
            indeg[id.0] = self.in_degree(id);
        }
        // BinaryHeap-free deterministic frontier: sorted insertion is
        // O(n log n) overall using a min-ordered Vec used as a stack over
        // reverse-sorted ids. For placement-scale graphs a simple
        // BinaryHeap<Reverse<usize>> is clearer and fast.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut frontier: BinaryHeap<Reverse<usize>> = self
            .node_ids()
            .filter(|&id| indeg[id.0] == 0)
            .map(|id| Reverse(id.0))
            .collect();
        let mut order = Vec::with_capacity(live);
        while let Some(Reverse(u)) = frontier.pop() {
            let u = NodeId(u);
            order.push(u);
            for &(v, _) in self.successors(u) {
                indeg[v.0] -= 1;
                if indeg[v.0] == 0 {
                    frontier.push(Reverse(v.0));
                }
            }
        }
        if order.len() == live {
            Some(order)
        } else {
            None
        }
    }

    /// True if the live graph is acyclic.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Position of each node in the topological order (`usize::MAX` for
    /// dead nodes). Panics on cyclic graphs.
    pub fn topo_ranks(&self) -> Vec<usize> {
        let order = self.topo_order().expect("topo_ranks on cyclic graph");
        let mut ranks = vec![usize::MAX; self.capacity()];
        for (rank, id) in order.iter().enumerate() {
            ranks[id.0] = rank;
        }
        ranks
    }

    /// Depth of each node = longest hop-count path from any source.
    pub fn depths(&self) -> Vec<usize> {
        let order = self.topo_order().expect("depths on cyclic graph");
        let mut depth = vec![0usize; self.capacity()];
        for &u in &order {
            for &(v, _) in self.successors(u) {
                depth[v.0] = depth[v.0].max(depth[u.0] + 1);
            }
        }
        depth
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{OpGraph, OpKind};

    #[test]
    fn topo_respects_edges() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        g.add_edge(b, d, 1);
        g.add_edge(c, d, 1);
        let order = g.topo_order().unwrap();
        let rank = g.topo_ranks();
        for e in g.edges() {
            assert!(rank[e.src.0] < rank[e.dst.0]);
        }
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], a);
        assert_eq!(order[3], d);
    }

    #[test]
    fn cycle_detected() {
        let mut g = OpGraph::new("c");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.add_edge(b, c, 1);
        g.add_edge(c, a, 1);
        assert!(g.topo_order().is_none());
        assert!(!g.is_acyclic());
    }

    #[test]
    fn topo_skips_dead_nodes() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::Loss);
        g.add_edge(a, b, 1);
        g.add_edge(b, c, 1);
        g.remove_node(b);
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 2);
        assert!(!order.contains(&b));
    }

    #[test]
    fn depths_longest_path() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        g.add_edge(a, d, 1); // short path
        g.add_edge(a, b, 1);
        g.add_edge(b, c, 1);
        g.add_edge(c, d, 1); // long path
        let depth = g.depths();
        assert_eq!(depth[a.0], 0);
        assert_eq!(depth[d.0], 3);
    }
}
