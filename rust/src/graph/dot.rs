//! Graphviz DOT export for debugging placements.
//!
//! [`OpGraph::to_dot`] colors nodes by device;
//! [`OpGraph::to_dot_topology`] additionally groups devices into their
//! topology islands (dashed subgraph boxes) and highlights cross-island
//! edges in red, so a placement's expensive cut edges are visually
//! auditable.

use super::{DeviceId, NodeId, OpGraph};
use crate::topology::Topology;
use std::collections::BTreeMap;

/// Color palette cycled per device.
const COLORS: [&str; 8] = [
    "lightblue", "lightsalmon", "palegreen", "plum", "khaki", "lightcyan", "mistyrose", "wheat",
];

impl OpGraph {
    /// Render the graph in DOT, optionally coloring by placement.
    pub fn to_dot(&self, placement: Option<&BTreeMap<NodeId, DeviceId>>) -> String {
        let mut s = String::from("digraph G {\n  rankdir=TB;\n  node [shape=box, style=filled];\n");
        for n in self.iter_nodes() {
            let color = placement
                .and_then(|p| p.get(&n.id))
                .map(|d| COLORS[d.0 % COLORS.len()])
                .unwrap_or("white");
            s.push_str(&format!(
                "  {} [label=\"{}\\n{:.2}ms\", fillcolor={}];\n",
                n.id.0,
                n.name.replace('"', "'"),
                n.compute * 1e3,
                color
            ));
        }
        for e in self.edges() {
            s.push_str(&format!(
                "  {} -> {} [label=\"{}\"];\n",
                e.src.0, e.dst.0, e.bytes
            ));
        }
        s.push_str("}\n");
        s
    }

    /// Render a placed graph with device clusters grouped by topology
    /// island and cross-island edges highlighted.
    pub fn to_dot_topology(
        &self,
        placement: &BTreeMap<NodeId, DeviceId>,
        topo: &Topology,
    ) -> String {
        let island_of = |id: NodeId| -> Option<usize> {
            placement
                .get(&id)
                .filter(|d| d.0 < topo.n())
                .map(|d| topo.island_of(d.0))
        };
        let mut s = String::from(
            "digraph G {\n  rankdir=TB;\n  node [shape=box, style=filled];\n",
        );
        for isl in 0..topo.n_islands() {
            s.push_str(&format!(
                "  subgraph cluster_{isl} {{\n    label=\"island {isl}\";\n    style=dashed;\n"
            ));
            for n in self.iter_nodes() {
                if island_of(n.id) != Some(isl) {
                    continue;
                }
                let d = placement[&n.id];
                s.push_str(&format!(
                    "    {} [label=\"{}\\n{} · {:.2}ms\", fillcolor={}];\n",
                    n.id.0,
                    n.name.replace('"', "'"),
                    d,
                    n.compute * 1e3,
                    COLORS[d.0 % COLORS.len()]
                ));
            }
            s.push_str("  }\n");
        }
        // Unplaced (or out-of-range) nodes sit outside every island.
        for n in self.iter_nodes() {
            if island_of(n.id).is_none() {
                s.push_str(&format!(
                    "  {} [label=\"{}\\n{:.2}ms\", fillcolor=white];\n",
                    n.id.0,
                    n.name.replace('"', "'"),
                    n.compute * 1e3
                ));
            }
        }
        for e in self.edges() {
            let cross = match (island_of(e.src), island_of(e.dst)) {
                (Some(a), Some(b)) => a != b,
                _ => false,
            };
            if cross {
                s.push_str(&format!(
                    "  {} -> {} [label=\"{}\", color=red, penwidth=2];\n",
                    e.src.0, e.dst.0, e.bytes
                ));
            } else {
                s.push_str(&format!(
                    "  {} -> {} [label=\"{}\"];\n",
                    e.src.0, e.dst.0, e.bytes
                ));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{DeviceId, OpGraph, OpKind};
    use std::collections::BTreeMap;

    #[test]
    fn dot_contains_nodes_and_edges() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("alpha", OpKind::Input);
        let b = g.add_node("beta", OpKind::MatMul);
        g.add_edge(a, b, 42);
        let mut p = BTreeMap::new();
        p.insert(a, DeviceId(0));
        p.insert(b, DeviceId(1));
        let dot = g.to_dot(Some(&p));
        assert!(dot.contains("alpha"));
        assert!(dot.contains("-> 1"));
        assert!(dot.contains("lightblue"));
        assert!(dot.contains("lightsalmon"));
        assert!(dot.contains("label=\"42\""));
    }

    #[test]
    fn topology_dot_groups_islands_and_flags_cut_edges() {
        use crate::profile::CommModel;
        use crate::topology::Topology;
        let mut g = OpGraph::new("t");
        let a = g.add_node("alpha", OpKind::Input);
        let b = g.add_node("beta", OpKind::MatMul);
        let c = g.add_node("gamma", OpKind::MatMul);
        g.add_edge(a, b, 7); // intra-island
        g.add_edge(b, c, 42); // cross-island
        let topo = Topology::nvlink_islands(
            4,
            2,
            CommModel::nvlink_like(),
            CommModel::pcie_via_host(),
        )
        .unwrap();
        let mut p = BTreeMap::new();
        p.insert(a, DeviceId(0));
        p.insert(b, DeviceId(1));
        p.insert(c, DeviceId(2));
        let dot = g.to_dot_topology(&p, &topo);
        assert!(dot.contains("subgraph cluster_0"));
        assert!(dot.contains("subgraph cluster_1"));
        assert!(dot.contains("label=\"island 1\""));
        // The cross-island edge is highlighted; the intra one is not.
        assert!(dot.contains("1 -> 2 [label=\"42\", color=red, penwidth=2]"));
        assert!(dot.contains("0 -> 1 [label=\"7\"]"));
        // Unplaced nodes render outside the clusters.
        let partial: BTreeMap<_, _> = [(a, DeviceId(0))].into_iter().collect();
        let dot2 = g.to_dot_topology(&partial, &topo);
        assert!(dot2.contains("fillcolor=white"));
    }
}
