//! Graphviz DOT export for debugging placements.

use super::{DeviceId, NodeId, OpGraph};
use std::collections::BTreeMap;

/// Color palette cycled per device.
const COLORS: [&str; 8] = [
    "lightblue", "lightsalmon", "palegreen", "plum", "khaki", "lightcyan", "mistyrose", "wheat",
];

impl OpGraph {
    /// Render the graph in DOT, optionally coloring by placement.
    pub fn to_dot(&self, placement: Option<&BTreeMap<NodeId, DeviceId>>) -> String {
        let mut s = String::from("digraph G {\n  rankdir=TB;\n  node [shape=box, style=filled];\n");
        for n in self.iter_nodes() {
            let color = placement
                .and_then(|p| p.get(&n.id))
                .map(|d| COLORS[d.0 % COLORS.len()])
                .unwrap_or("white");
            s.push_str(&format!(
                "  {} [label=\"{}\\n{:.2}ms\", fillcolor={}];\n",
                n.id.0,
                n.name.replace('"', "'"),
                n.compute * 1e3,
                color
            ));
        }
        for e in self.edges() {
            s.push_str(&format!(
                "  {} -> {} [label=\"{}\"];\n",
                e.src.0, e.dst.0, e.bytes
            ));
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{DeviceId, OpGraph, OpKind};
    use std::collections::BTreeMap;

    #[test]
    fn dot_contains_nodes_and_edges() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("alpha", OpKind::Input);
        let b = g.add_node("beta", OpKind::MatMul);
        g.add_edge(a, b, 42);
        let mut p = BTreeMap::new();
        p.insert(a, DeviceId(0));
        p.insert(b, DeviceId(1));
        let dot = g.to_dot(Some(&p));
        assert!(dot.contains("alpha"));
        assert!(dot.contains("-> 1"));
        assert!(dot.contains("lightblue"));
        assert!(dot.contains("lightsalmon"));
        assert!(dot.contains("label=\"42\""));
    }
}
