//! The annotated operator DAG consumed by every stage of Baechi.
//!
//! Mirrors the paper's NetworkX intermediate representation (§4.1): each
//! node is an operator (TensorFlow) or module (PyTorch) annotated with its
//! profiled compute time, the five-component memory model of paper Table 2,
//! and the size of its output tensor; each edge carries the bytes
//! communicated if its endpoints land on different devices.

pub mod builder;
pub mod csr;
pub mod delta;
pub mod dot;
pub mod topo;

use std::collections::BTreeMap;

/// Index of a node in an [`OpGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Index of a device in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeviceId(pub usize);

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// Five-component memory model (paper §4.1.1, Table 2), in bytes.
///
/// | component        | training                | inference        |
/// |------------------|-------------------------|------------------|
/// | permanent        | params + output + grads | params           |
/// | temporary        | temp + upstream grad    | temp + output    |
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemorySpec {
    /// (a) parameter memory (weights).
    pub params: u64,
    /// (b) forward-output tensor memory.
    pub output: u64,
    /// (c) parameter-gradient memory.
    pub param_grad: u64,
    /// (d) upstream (output) gradient memory.
    pub upstream_grad: u64,
    /// (e) scratch used while computing the output/gradients.
    pub temp: u64,
}

impl MemorySpec {
    /// Permanent bytes held for the whole training run (Table 2, training).
    pub fn permanent_training(&self) -> u64 {
        self.params + self.output + self.param_grad
    }

    /// Peak temporary bytes during training.
    pub fn temporary_training(&self) -> u64 {
        self.temp + self.upstream_grad
    }

    /// Permanent bytes during inference.
    pub fn permanent_inference(&self) -> u64 {
        self.params
    }

    /// Peak temporary bytes during inference.
    pub fn temporary_inference(&self) -> u64 {
        self.temp + self.output
    }

    /// Total budget the placer must account for on the hosting device.
    pub fn total_training(&self) -> u64 {
        self.permanent_training() + self.temporary_training()
    }

    /// Component-wise sum (used when fusing operators).
    pub fn merge(&self, other: &MemorySpec) -> MemorySpec {
        MemorySpec {
            params: self.params + other.params,
            output: self.output + other.output,
            param_grad: self.param_grad + other.param_grad,
            upstream_grad: self.upstream_grad.max(other.upstream_grad),
            temp: self.temp.max(other.temp),
        }
    }
}

/// Operator kind — used by the cost model, the runtime artifact registry,
/// and the expert placers.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matmul / fully-connected layer.
    MatMul,
    /// Convolution (modelled as an implicit-GEMM matmul on TPU).
    Conv2d,
    /// LSTM cell (fused gates).
    LstmCell,
    /// Scaled-dot-product attention.
    Attention,
    /// Embedding lookup.
    Embedding,
    /// Elementwise / activation / normalization and other cheap ops.
    Elementwise,
    /// Pooling.
    Pool,
    /// Concat / split / reshape plumbing.
    Shape,
    /// Loss computation.
    Loss,
    /// Optimizer state update (e.g. ApplyGradient).
    ApplyGrad,
    /// Variable read/assign (TF colocation-constrained ops).
    Variable,
    /// Input pipeline / constant.
    Input,
    /// Anything else.
    Generic(u32),
}

impl OpKind {
    pub fn name(&self) -> String {
        match self {
            OpKind::Generic(k) => format!("generic{k}"),
            other => format!("{other:?}").to_lowercase(),
        }
    }
}

/// A graph node: one operator (or fused meta-operator).
#[derive(Debug, Clone)]
pub struct OpNode {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    /// Profiled compute time, seconds.
    pub compute: f64,
    /// Five-component memory footprint.
    pub mem: MemorySpec,
    /// Bytes of this op's output tensor (what successors receive).
    pub output_bytes: u64,
    /// TensorFlow colocation-constraint group (§3.1.1), if any.
    pub colocation_group: Option<String>,
    /// Co-placement group chosen by the optimizer (§3.1.2), if any.
    pub coplacement_group: Option<String>,
    /// True for backward (gradient) operators.
    pub is_backward: bool,
    /// The forward op this backward op matches (for fwd/bwd co-placement).
    pub forward_of: Option<NodeId>,
    /// Original node ids folded into this node by operator fusion.
    pub fused_from: Vec<NodeId>,
}

impl OpNode {
    fn new(id: NodeId, name: &str, kind: OpKind) -> OpNode {
        OpNode {
            id,
            name: name.to_string(),
            kind,
            compute: 0.0,
            mem: MemorySpec::default(),
            output_bytes: 0,
            colocation_group: None,
            coplacement_group: None,
            is_backward: false,
            forward_of: None,
            fused_from: Vec::new(),
        }
    }
}

/// A directed edge with the bytes communicated along it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
}

/// The operator DAG.
///
/// Nodes are stored densely; removal is handled by tombstoning (`alive`)
/// so `NodeId`s stay stable across optimizer passes.
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    pub name: String,
    nodes: Vec<OpNode>,
    alive: Vec<bool>,
    out_edges: Vec<Vec<(NodeId, u64)>>,
    in_edges: Vec<Vec<(NodeId, u64)>>,
}

impl OpGraph {
    pub fn new(name: &str) -> OpGraph {
        OpGraph {
            name: name.to_string(),
            ..Default::default()
        }
    }

    /// Add a node and return its id.
    pub fn add_node(&mut self, name: &str, kind: OpKind) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(OpNode::new(id, name, kind));
        self.alive.push(true);
        self.out_edges.push(Vec::new());
        self.in_edges.push(Vec::new());
        id
    }

    /// Add an edge carrying `bytes`; duplicate (src,dst) edges are merged
    /// by taking the max byte count (one physical transfer per tensor —
    /// the ES caches tensors per destination device, §4.2).
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, bytes: u64) {
        assert_ne!(src, dst, "self edge");
        assert!(self.alive[src.0] && self.alive[dst.0], "edge to dead node");
        if let Some(e) = self.out_edges[src.0].iter_mut().find(|(d, _)| *d == dst) {
            e.1 = e.1.max(bytes);
            if let Some(ie) = self.in_edges[dst.0].iter_mut().find(|(s, _)| *s == src) {
                ie.1 = ie.1.max(bytes);
            }
            return;
        }
        self.out_edges[src.0].push((dst, bytes));
        self.in_edges[dst.0].push((src, bytes));
    }

    /// Immutable node access. Panics on dead nodes in debug builds.
    pub fn node(&self, id: NodeId) -> &OpNode {
        debug_assert!(self.alive[id.0], "access to dead node {id}");
        &self.nodes[id.0]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut OpNode {
        debug_assert!(self.alive[id.0], "access to dead node {id}");
        &mut self.nodes[id.0]
    }

    pub fn is_alive(&self, id: NodeId) -> bool {
        self.alive[id.0]
    }

    /// Tombstone a node, detaching all its edges.
    pub fn remove_node(&mut self, id: NodeId) {
        assert!(self.alive[id.0]);
        let outs: Vec<NodeId> = self.out_edges[id.0].iter().map(|(d, _)| *d).collect();
        for d in outs {
            self.in_edges[d.0].retain(|(s, _)| *s != id);
        }
        let ins: Vec<NodeId> = self.in_edges[id.0].iter().map(|(s, _)| *s).collect();
        for s in ins {
            self.out_edges[s.0].retain(|(d, _)| *d != id);
        }
        self.out_edges[id.0].clear();
        self.in_edges[id.0].clear();
        self.alive[id.0] = false;
    }

    /// Live node count.
    pub fn len(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total allocated slots (dead + alive); `NodeId`s are `< capacity()`.
    pub fn capacity(&self) -> usize {
        self.nodes.len()
    }

    /// Iterate live node ids.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.alive
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .map(|(i, _)| NodeId(i))
    }

    /// Iterate live nodes.
    pub fn iter_nodes(&self) -> impl Iterator<Item = &OpNode> {
        self.node_ids().map(|id| &self.nodes[id.0])
    }

    /// Successors with edge bytes.
    pub fn successors(&self, id: NodeId) -> &[(NodeId, u64)] {
        &self.out_edges[id.0]
    }

    /// Predecessors with edge bytes.
    pub fn predecessors(&self, id: NodeId) -> &[(NodeId, u64)] {
        &self.in_edges[id.0]
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.out_edges[id.0].len()
    }

    pub fn in_degree(&self, id: NodeId) -> usize {
        self.in_edges[id.0].len()
    }

    /// Bytes on the edge `src → dst`, if present.
    pub fn edge_bytes(&self, src: NodeId, dst: NodeId) -> Option<u64> {
        self.out_edges[src.0]
            .iter()
            .find(|(d, _)| *d == dst)
            .map(|(_, b)| *b)
    }

    /// All live edges.
    pub fn edges(&self) -> Vec<Edge> {
        let mut es = Vec::new();
        for src in self.node_ids() {
            for &(dst, bytes) in &self.out_edges[src.0] {
                es.push(Edge { src, dst, bytes });
            }
        }
        es
    }

    pub fn edge_count(&self) -> usize {
        self.node_ids().map(|id| self.out_edges[id.0].len()).sum()
    }

    /// Source nodes (no predecessors).
    pub fn sources(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&id| self.in_edges[id.0].is_empty())
            .collect()
    }

    /// Sink nodes (no successors).
    pub fn sinks(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|&id| self.out_edges[id.0].is_empty())
            .collect()
    }

    /// Sum of compute times over live nodes, seconds.
    pub fn total_compute(&self) -> f64 {
        self.iter_nodes().map(|n| n.compute).sum()
    }

    /// Sum of permanent training memory over live nodes, bytes.
    pub fn total_permanent_memory(&self) -> u64 {
        self.iter_nodes().map(|n| n.mem.permanent_training()).sum()
    }

    /// Largest single-node permanent training memory, bytes.
    pub fn max_node_memory(&self) -> u64 {
        self.iter_nodes()
            .map(|n| n.mem.permanent_training())
            .max()
            .unwrap_or(0)
    }

    /// Ratio of max edge communication time to min node computation time
    /// (the paper's ρ; SCT assumption holds iff ρ ≤ 1). `comm` converts
    /// bytes to seconds.
    pub fn rho(&self, comm: impl Fn(u64) -> f64) -> f64 {
        let max_comm = self
            .edges()
            .iter()
            .map(|e| comm(e.bytes))
            .fold(0.0f64, f64::max);
        let min_comp = self
            .iter_nodes()
            .map(|n| n.compute)
            .filter(|&c| c > 0.0)
            .fold(f64::INFINITY, f64::min);
        if min_comp.is_finite() && min_comp > 0.0 {
            max_comm / min_comp
        } else {
            f64::INFINITY
        }
    }

    /// True if `dst` is reachable from `src` (DFS).
    pub fn reachable(&self, src: NodeId, dst: NodeId) -> bool {
        if src == dst {
            return true;
        }
        let mut seen = vec![false; self.capacity()];
        let mut stack = vec![src];
        seen[src.0] = true;
        while let Some(u) = stack.pop() {
            for &(v, _) in &self.out_edges[u.0] {
                if v == dst {
                    return true;
                }
                if !seen[v.0] {
                    seen[v.0] = true;
                    stack.push(v);
                }
            }
        }
        false
    }

    /// Critical (longest) path length, with `comm` charging every edge as
    /// if endpoints were on different devices. A lower bound on makespan
    /// with communication; with `|_| 0.0` it is the zero-comm lower bound.
    /// Errors with [`crate::BaechiError::Cyclic`] on a non-DAG instead
    /// of panicking, so callers handling untrusted graphs get a typed
    /// failure.
    pub fn critical_path(&self, comm: impl Fn(u64) -> f64) -> crate::Result<f64> {
        let order = self.topo_order().ok_or(crate::BaechiError::Cyclic)?;
        let mut dist: Vec<f64> = vec![0.0; self.capacity()];
        let mut best = 0.0f64;
        for &u in &order {
            let finish = dist[u.0] + self.nodes[u.0].compute;
            best = best.max(finish);
            for &(v, bytes) in &self.out_edges[u.0] {
                let cand = finish + comm(bytes);
                if cand > dist[v.0] {
                    dist[v.0] = cand;
                }
            }
        }
        Ok(best)
    }

    /// Map of colocation group → member nodes.
    pub fn colocation_groups(&self) -> BTreeMap<String, Vec<NodeId>> {
        let mut groups: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
        for n in self.iter_nodes() {
            if let Some(g) = &n.colocation_group {
                groups.entry(g.clone()).or_default().push(n.id);
            }
        }
        groups
    }

    /// Number of live forward (non-backward) operators.
    pub fn forward_count(&self) -> usize {
        self.iter_nodes().filter(|n| !n.is_backward).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> (OpGraph, [NodeId; 4]) {
        // a → b → d, a → c → d
        let mut g = OpGraph::new("diamond");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        g.add_edge(a, b, 10);
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 20);
        g.add_edge(c, d, 20);
        (g, [a, b, c, d])
    }

    #[test]
    fn basic_structure() {
        let (g, [a, b, _c, d]) = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.sources(), vec![a]);
        assert_eq!(g.sinks(), vec![d]);
        assert_eq!(g.out_degree(a), 2);
        assert_eq!(g.in_degree(d), 2);
        assert_eq!(g.edge_bytes(a, b), Some(10));
        assert_eq!(g.edge_bytes(b, a), None);
    }

    #[test]
    fn duplicate_edge_merged() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 10);
        g.add_edge(a, b, 30);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.edge_bytes(a, b), Some(30));
        assert_eq!(g.predecessors(b), &[(a, 30)]);
    }

    #[test]
    fn remove_node_detaches_edges() {
        let (mut g, [a, b, c, d]) = diamond();
        g.remove_node(b);
        assert_eq!(g.len(), 3);
        assert!(!g.is_alive(b));
        assert_eq!(g.out_degree(a), 1);
        assert_eq!(g.in_degree(d), 1);
        assert!(g.reachable(a, d)); // via c
        let _ = c;
    }

    #[test]
    fn reachability() {
        let (g, [a, b, c, d]) = diamond();
        assert!(g.reachable(a, d));
        assert!(g.reachable(a, a));
        assert!(!g.reachable(d, a));
        assert!(!g.reachable(b, c));
    }

    #[test]
    fn critical_path_with_comm() {
        let (mut g, [a, b, c, d]) = diamond();
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 2.0;
        g.node_mut(c).compute = 5.0;
        g.node_mut(d).compute = 1.0;
        // zero comm: a + c + d = 7
        assert!((g.critical_path(|_| 0.0).unwrap() - 7.0).abs() < 1e-12);
        // comm = bytes/10 seconds: a +1 + c +2 + d = 10
        assert!((g.critical_path(|b| b as f64 / 10.0).unwrap() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn critical_path_cyclic_is_typed_error() {
        let mut g = OpGraph::new("cycle");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.add_edge(b, a, 1);
        assert!(matches!(
            g.critical_path(|_| 0.0),
            Err(crate::BaechiError::Cyclic)
        ));
    }

    #[test]
    fn memory_spec_table2() {
        let m = MemorySpec {
            params: 100,
            output: 50,
            param_grad: 100,
            upstream_grad: 50,
            temp: 30,
        };
        assert_eq!(m.permanent_training(), 250);
        assert_eq!(m.temporary_training(), 80);
        assert_eq!(m.permanent_inference(), 100);
        assert_eq!(m.temporary_inference(), 80);
    }

    #[test]
    fn rho_computation() {
        let (mut g, [a, b, c, d]) = diamond();
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 2.0;
        }
        // max comm = 20 bytes * 0.05 = 1.0 s; min comp 2.0 → rho = 0.5
        let rho = g.rho(|bytes| bytes as f64 * 0.05);
        assert!((rho - 0.5).abs() < 1e-12);
    }

    #[test]
    fn colocation_groups_collected() {
        let (mut g, [a, b, _, _]) = diamond();
        g.node_mut(a).colocation_group = Some("w".into());
        g.node_mut(b).colocation_group = Some("w".into());
        let groups = g.colocation_groups();
        assert_eq!(groups["w"], vec![a, b]);
    }
}
