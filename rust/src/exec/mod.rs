//! Real multi-device execution of a placed model (the end-to-end
//! deliverable).
//!
//! Each simulated device is an OS thread owning its own PJRT CPU client
//! and compiled artifacts; devices exchange tensors over bounded
//! channels, mirroring the Baechi-PY communication protocol (§3.2.2):
//! outputs are pushed greedily to consumer devices, consumers block on
//! their rx channels — the tx/rx stream pairs become channel endpoints.
//! An optional calibrated delay models the interconnect (DESIGN.md §2:
//! compute is real, the wire is modeled).
//!
//! The concrete workload is the AOT-compiled MLP from
//! `python/compile/model.py`, placed at module granularity by any
//! [`crate::placer::Placer`]; [`trainer`] drives training steps and
//! validates the distributed numerics against the fused `train_step`
//! oracle artifact.

pub mod plan;
pub mod trainer;
pub mod worker;

use crate::error::BaechiError;
use crate::runtime::xla;

/// A host-side tensor (f32, row-major) — the wire format between device
/// threads. PJRT literals are not `Send`, so transfers materialize
/// through host memory exactly like the paper's no-P2P testbed (§5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl HostTensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> HostTensor {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        HostTensor { data, dims }
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor {
            data: vec![v],
            dims: vec![],
        }
    }

    pub fn bytes(&self) -> u64 {
        4 * self.data.len() as u64
    }

    pub fn to_literal(&self) -> crate::Result<xla::Literal> {
        if self.dims.is_empty() {
            // rank-0 scalar
            let lit = xla::Literal::vec1(&self.data);
            return Ok(lit.reshape(&[])?);
        }
        Ok(xla::Literal::vec1(&self.data).reshape(&self.dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> crate::Result<HostTensor> {
        let shape = lit.shape()?;
        let dims: Vec<i64> = match &shape {
            xla::Shape::Array(a) => a.dims().to_vec(),
            _ => return Err(BaechiError::runtime("non-array literal")),
        };
        Ok(HostTensor {
            data: lit.to_vec::<f32>()?,
            dims,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(3.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![3.5]);
        assert!(back.dims.is_empty());
    }

    #[test]
    fn bytes_accounting() {
        let t = HostTensor::new(vec![0.0; 16], vec![4, 4]);
        assert_eq!(t.bytes(), 64);
    }
}
