//! Device worker thread: owns a PJRT client + compiled artifacts for its
//! assigned pipeline stages, exchanges tensors with peer devices over
//! channels, and applies SGD updates to its resident parameters.
//!
//! This is the runtime realization of the Baechi-PY protocol (§3.2.2):
//! outputs are pushed greedily to consumer devices as soon as computed
//! (the `tx` side), and a stage blocks on its inbox until all inputs
//! have arrived (the `wait` side). Parameters never move: each layer's
//! weights live on the device the placer chose.

use super::plan::MlpPlan;
use super::HostTensor;
use crate::error::BaechiError;
use crate::profile::CommModel;
use crate::runtime::artifact::ArtifactRegistry;
use crate::runtime::Runtime;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};

/// Inter-thread message.
#[derive(Debug)]
pub enum Msg {
    Tensor { key: String, t: HostTensor },
    Loss { step: usize, value: f32 },
    /// Worker error (panics are converted at join).
    Error(String),
}

/// One pipeline stage (global order: F0..F{L-1}, LF, LB, B{L-1}..B0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Stage {
    Fwd(usize),
    LossFwd,
    LossBwd,
    Bwd(usize),
}

/// Global stage order for an L-layer MLP.
pub fn stage_order(n_layers: usize) -> Vec<Stage> {
    let mut v: Vec<Stage> = (0..n_layers).map(Stage::Fwd).collect();
    v.push(Stage::LossFwd);
    v.push(Stage::LossBwd);
    v.extend((0..n_layers).rev().map(Stage::Bwd));
    v
}

/// Device for a stage under a plan.
pub fn stage_device(plan: &MlpPlan, s: Stage) -> usize {
    match s {
        Stage::Fwd(i) | Stage::Bwd(i) => plan.layer_dev[i],
        Stage::LossFwd | Stage::LossBwd => plan.loss_dev,
    }
}

/// Configuration passed to each worker thread.
pub struct WorkerCfg {
    pub dev: usize,
    pub plan: MlpPlan,
    pub steps: usize,
    pub lr: f32,
    pub artifacts_dir: PathBuf,
    /// Initial parameters for the layers this device hosts: (layer, w, b).
    pub params: Vec<(usize, HostTensor, HostTensor)>,
    /// Sleep `comm.time(bytes)` before each cross-device send, modeling
    /// the interconnect (None = raw channel speed).
    pub comm: Option<CommModel>,
}

/// Run the worker loop (body of the device thread). Returns the final
/// parameters of its layers.
pub fn run_worker(
    cfg: WorkerCfg,
    inbox: Receiver<Msg>,
    peers: Vec<Sender<Msg>>,
    main_tx: Sender<Msg>,
) -> crate::Result<Vec<(usize, HostTensor, HostTensor)>> {
    let runtime = Runtime::cpu()?;
    let registry = ArtifactRegistry::open(runtime, &cfg.artifacts_dir)?;
    let n_layers = cfg.plan.layer_dev.len();
    let my_stages: Vec<Stage> = stage_order(n_layers)
        .into_iter()
        .filter(|&s| stage_device(&cfg.plan, s) == cfg.dev)
        .collect();
    let mut params: HashMap<usize, (HostTensor, HostTensor)> = cfg
        .params
        .iter()
        .map(|(l, w, b)| (*l, (w.clone(), b.clone())))
        .collect();

    // Per-step local tensor store.
    let mut store: HashMap<String, HostTensor> = HashMap::new();
    let recv_into =
        |store: &mut HashMap<String, HostTensor>, key: &str| -> crate::Result<HostTensor> {
            if let Some(t) = store.remove(key) {
                return Ok(t);
            }
            loop {
                match inbox.recv() {
                    Ok(Msg::Tensor { key: k, t }) => {
                        if k == key {
                            return Ok(t);
                        }
                        store.insert(k, t);
                    }
                    Ok(other) => {
                        return Err(BaechiError::runtime(format!(
                            "unexpected message {other:?}"
                        )))
                    }
                    Err(_) => {
                        return Err(BaechiError::runtime(format!(
                            "inbox closed waiting for {key}"
                        )))
                    }
                }
            }
        };
    // Peek without consuming (for residuals needed again later).
    let fetch_keep = |store: &HashMap<String, HostTensor>, key: &str| -> Option<HostTensor> {
        store.get(key).cloned()
    };

    let send_to = |dev: usize, key: &str, t: &HostTensor, peers: &[Sender<Msg>]| {
        if let Some(comm) = &cfg.comm {
            let secs = comm.time(t.bytes());
            if secs > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(secs));
            }
        }
        let _ = peers[dev].send(Msg::Tensor {
            key: key.to_string(),
            t: t.clone(),
        });
    };

    for step in 0..cfg.steps {
        // Drop leftovers from completed steps (keys are "name/step";
        // tensors for future steps may already have arrived and must
        // survive).
        store.retain(|k, _| {
            k.rsplit('/')
                .next()
                .and_then(|s| s.parse::<usize>().ok())
                .map(|s| s >= step)
                .unwrap_or(true)
        });
        for &stage in &my_stages {
            match stage {
                Stage::Fwd(i) => {
                    let a_key = format!("a{i}/{step}");
                    // `a_i` is both this stage's input and B(i)'s residual:
                    // keep it in the store.
                    let a = match fetch_keep(&store, &a_key) {
                        Some(t) => t,
                        None => {
                            let t = recv_into(&mut store, &a_key)?;
                            store.insert(a_key.clone(), t.clone());
                            t
                        }
                    };
                    let (w, b) = params.get(&i).expect("layer params resident").clone();
                    let exec = registry.load(&format!("layer{i}_fwd"))?;
                    let outs = exec.run(&[a.to_literal()?, w.to_literal()?, b.to_literal()?])?;
                    let y = HostTensor::from_literal(&outs[0])?;
                    let y_key = format!("a{}/{step}", i + 1);
                    // Residual for B(i) (same device) and input for F(i+1).
                    store.insert(y_key.clone(), y.clone());
                    let next_dev = if i + 1 < n_layers {
                        cfg.plan.layer_dev[i + 1]
                    } else {
                        cfg.plan.loss_dev
                    };
                    if next_dev != cfg.dev {
                        send_to(next_dev, &y_key, &y, &peers);
                    }
                }
                Stage::LossFwd => {
                    let logits_key = format!("a{n_layers}/{step}");
                    let logits = match fetch_keep(&store, &logits_key) {
                        Some(t) => t,
                        None => {
                            let t = recv_into(&mut store, &logits_key)?;
                            store.insert(logits_key.clone(), t.clone());
                            t
                        }
                    };
                    let onehot = match fetch_keep(&store, &format!("onehot/{step}")) {
                        Some(t) => t,
                        None => {
                            let t = recv_into(&mut store, &format!("onehot/{step}"))?;
                            store.insert(format!("onehot/{step}"), t.clone());
                            t
                        }
                    };
                    let exec = registry.load("loss_fwd")?;
                    let outs = exec.run(&[logits.to_literal()?, onehot.to_literal()?])?;
                    let loss = HostTensor::from_literal(&outs[0])?;
                    let probs = HostTensor::from_literal(&outs[1])?;
                    store.insert(format!("probs/{step}"), probs);
                    let _ = main_tx.send(Msg::Loss {
                        step,
                        value: loss.data[0],
                    });
                }
                Stage::LossBwd => {
                    let probs = store
                        .remove(&format!("probs/{step}"))
                        .expect("probs resident (loss fwd/bwd colocated)");
                    let onehot = fetch_keep(&store, &format!("onehot/{step}"))
                        .expect("onehot resident");
                    let exec = registry.load("loss_bwd")?;
                    let outs = exec.run(&[probs.to_literal()?, onehot.to_literal()?])?;
                    let dy = HostTensor::from_literal(&outs[0])?;
                    let key = format!("dy{n_layers}/{step}");
                    let dst = cfg.plan.layer_dev[n_layers - 1];
                    if dst != cfg.dev {
                        send_to(dst, &key, &dy, &peers);
                    } else {
                        store.insert(key, dy);
                    }
                }
                Stage::Bwd(i) => {
                    let dy_key = format!("dy{}/{step}", i + 1);
                    let dy = match store.remove(&dy_key) {
                        Some(t) => t,
                        None => recv_into(&mut store, &dy_key)?,
                    };
                    // Residuals are shared (a_{i+1} is layer i's `y` AND
                    // layer i+1's `x`): read without consuming; the
                    // step-start retain reclaims them.
                    let x = fetch_keep(&store, &format!("a{i}/{step}"))
                        .expect("residual x resident (fwd/bwd colocated)");
                    let y = fetch_keep(&store, &format!("a{}/{step}", i + 1))
                        .unwrap_or_else(|| panic!("residual y of layer {i} resident"));
                    let (w, b) = params.get(&i).expect("params resident").clone();
                    let exec = registry.load(&format!("layer{i}_bwd"))?;
                    let outs = exec.run(&[
                        x.to_literal()?,
                        w.to_literal()?,
                        y.to_literal()?,
                        dy.to_literal()?,
                    ])?;
                    let dx = HostTensor::from_literal(&outs[0])?;
                    let dw = HostTensor::from_literal(&outs[1])?;
                    let db = HostTensor::from_literal(&outs[2])?;
                    // Host-side SGD on the resident parameters.
                    let entry = params.get_mut(&i).unwrap();
                    for (wv, g) in entry.0.data.iter_mut().zip(&dw.data) {
                        *wv -= cfg.lr * g;
                    }
                    for (bv, g) in entry.1.data.iter_mut().zip(&db.data) {
                        *bv -= cfg.lr * g;
                    }
                    let _ = (w, b);
                    if i > 0 {
                        let key = format!("dy{i}/{step}");
                        let dst = cfg.plan.layer_dev[i - 1];
                        if dst != cfg.dev {
                            send_to(dst, &key, &dx, &peers);
                        } else {
                            store.insert(key, dx);
                        }
                    }
                }
            }
        }
    }

    Ok(params
        .into_iter()
        .map(|(l, (w, b))| (l, w, b))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_order_shape() {
        let order = stage_order(3);
        assert_eq!(
            order,
            vec![
                Stage::Fwd(0),
                Stage::Fwd(1),
                Stage::Fwd(2),
                Stage::LossFwd,
                Stage::LossBwd,
                Stage::Bwd(2),
                Stage::Bwd(1),
                Stage::Bwd(0),
            ]
        );
    }

    #[test]
    fn stage_device_mapping() {
        let plan = MlpPlan {
            layer_dev: vec![0, 1, 1],
            loss_dev: 1,
            n_devices: 2,
        };
        assert_eq!(stage_device(&plan, Stage::Fwd(0)), 0);
        assert_eq!(stage_device(&plan, Stage::Bwd(2)), 1);
        assert_eq!(stage_device(&plan, Stage::LossFwd), 1);
    }
}
