//! End-to-end trainer: drive real distributed training steps over the
//! placed MLP and validate against the fused `train_step` oracle
//! artifact (EXPERIMENTS.md §E2E).

use super::plan::MlpPlan;
use super::worker::{run_worker, Msg, WorkerCfg};
use super::HostTensor;
use crate::error::BaechiError;
use crate::profile::CommModel;
use crate::runtime::artifact::ArtifactRegistry;
use crate::runtime::Runtime;
use crate::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::mpsc;

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub artifacts_dir: PathBuf,
    /// Model the interconnect with calibrated sleeps (None = raw).
    pub comm: Option<CommModel>,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            steps: 100,
            lr: 0.05,
            seed: 42,
            artifacts_dir: ArtifactRegistry::default_dir(),
            comm: None,
        }
    }
}

/// Model hyper-parameters read from the artifact manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub batch: usize,
    pub classes: usize,
    /// (din, dout) per layer.
    pub layer_dims: Vec<(usize, usize)>,
}

impl ModelMeta {
    pub fn load(dir: &std::path::Path) -> crate::Result<ModelMeta> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let root = crate::util::json::Json::parse(&text)?;
        let batch = root
            .get("batch")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| BaechiError::invalid("manifest missing batch"))? as usize;
        let classes = root
            .get("classes")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| BaechiError::invalid("manifest missing classes"))?
            as usize;
        let layer_dims = root
            .get("layer_dims")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| BaechiError::invalid("manifest missing layer_dims"))?
            .iter()
            .map(|d| {
                let a = d.as_arr().unwrap();
                (a[0].as_u64().unwrap() as usize, a[1].as_u64().unwrap() as usize)
            })
            .collect();
        Ok(ModelMeta {
            batch,
            classes,
            layer_dims,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.layer_dims.len()
    }

    pub fn input_dim(&self) -> usize {
        self.layer_dims[0].0
    }
}

/// Training run report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub wall_time: f64,
    pub steps_per_sec: f64,
    pub plan: MlpPlan,
}

/// Deterministic He-initialized parameters: `[(w, b); layers]`.
pub fn init_params(meta: &ModelMeta, seed: u64) -> Vec<(HostTensor, HostTensor)> {
    let mut rng = Pcg::seed(seed);
    meta.layer_dims
        .iter()
        .map(|&(din, dout)| {
            let scale = (2.0 / din as f64).sqrt();
            let w: Vec<f32> = (0..din * dout)
                .map(|_| (rng.normal() * scale) as f32)
                .collect();
            (
                HostTensor::new(w, vec![din as i64, dout as i64]),
                HostTensor::new(vec![0.0; dout], vec![dout as i64]),
            )
        })
        .collect()
}

/// Deterministic synthetic batch: teacher-projection labels (mirrors
/// `python/compile/model.py::synthetic_batch`, but self-contained so the
/// rust binary needs no Python).
pub fn synthetic_batch(meta: &ModelMeta, step: usize, seed: u64) -> (HostTensor, HostTensor) {
    let din = meta.input_dim();
    let mut teacher_rng = Pcg::seed(seed ^ 0x7e4c);
    let teacher: Vec<f64> = (0..din * meta.classes).map(|_| teacher_rng.normal()).collect();
    let mut rng = Pcg::new(seed, step as u64 + 1);
    let x: Vec<f32> = (0..meta.batch * din).map(|_| rng.normal() as f32).collect();
    let mut onehot = vec![0.0f32; meta.batch * meta.classes];
    for r in 0..meta.batch {
        let mut best = (f64::NEG_INFINITY, 0);
        for c in 0..meta.classes {
            let mut acc = 0.0f64;
            for k in 0..din {
                acc += x[r * din + k] as f64 * teacher[k * meta.classes + c];
            }
            if acc > best.0 {
                best = (acc, c);
            }
        }
        onehot[r * meta.classes + best.1] = 1.0;
    }
    (
        HostTensor::new(x, vec![meta.batch as i64, din as i64]),
        HostTensor::new(onehot, vec![meta.batch as i64, meta.classes as i64]),
    )
}

/// Run distributed training per the plan. Spawns one worker thread per
/// device, streams batches in, and collects the loss curve.
pub fn train_distributed(plan: &MlpPlan, cfg: &TrainConfig) -> crate::Result<TrainReport> {
    let meta = ModelMeta::load(&cfg.artifacts_dir)?;
    let n_layers = meta.n_layers();
    if plan.layer_dev.len() != n_layers {
        return Err(BaechiError::invalid(format!(
            "plan layers {} != artifact layers {}",
            plan.layer_dev.len(),
            n_layers
        )));
    }
    let params = init_params(&meta, cfg.seed);

    // Channels: one inbox per device + the main inbox.
    let mut senders = Vec::new();
    let mut inboxes = Vec::new();
    for _ in 0..plan.n_devices {
        let (tx, rx) = mpsc::channel::<Msg>();
        senders.push(tx);
        inboxes.push(rx);
    }
    let (main_tx, main_rx) = mpsc::channel::<Msg>();

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (dev, inbox) in inboxes.into_iter().enumerate() {
        let wcfg = WorkerCfg {
            dev,
            plan: plan.clone(),
            steps: cfg.steps,
            lr: cfg.lr,
            artifacts_dir: cfg.artifacts_dir.clone(),
            params: params
                .iter()
                .enumerate()
                .filter(|(l, _)| plan.layer_dev[*l] == dev)
                .map(|(l, (w, b))| (l, w.clone(), b.clone()))
                .collect(),
            comm: cfg.comm,
        };
        let peers = senders.clone();
        let mtx = main_tx.clone();
        let err_tx = main_tx.clone();
        handles.push(std::thread::spawn(move || {
            if let Err(e) = run_worker(wcfg, inbox, peers, mtx) {
                let _ = err_tx.send(Msg::Error(format!("{e:#}")));
            }
        }));
    }
    drop(main_tx);

    // Stream batches.
    for step in 0..cfg.steps {
        let (x, onehot) = synthetic_batch(&meta, step, cfg.seed);
        senders[plan.layer_dev[0]]
            .send(Msg::Tensor {
                key: format!("a0/{step}"),
                t: x,
            })
            .map_err(|_| BaechiError::runtime("worker died"))?;
        senders[plan.loss_dev]
            .send(Msg::Tensor {
                key: format!("onehot/{step}"),
                t: onehot,
            })
            .map_err(|_| BaechiError::runtime("worker died"))?;
    }

    // Collect losses.
    let mut losses = vec![f32::NAN; cfg.steps];
    let mut got = 0;
    while got < cfg.steps {
        match main_rx.recv() {
            Ok(Msg::Loss { step, value }) => {
                losses[step] = value;
                got += 1;
            }
            Ok(Msg::Error(e)) => return Err(BaechiError::runtime(format!("worker error: {e}"))),
            Ok(_) => {}
            Err(_) => {
                return Err(BaechiError::runtime(
                    "workers exited before producing all losses",
                ))
            }
        }
    }
    drop(senders);
    for h in handles {
        h.join().map_err(|_| BaechiError::runtime("worker panicked"))?;
    }
    let wall_time = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps_per_sec: cfg.steps as f64 / wall_time,
        losses,
        wall_time,
        plan: plan.clone(),
    })
}

/// Oracle: run the fused `train_step` artifact single-device with the
/// same data and initial parameters.
pub fn train_oracle(cfg: &TrainConfig) -> crate::Result<Vec<f32>> {
    let meta = ModelMeta::load(&cfg.artifacts_dir)?;
    let runtime = Runtime::cpu()?;
    let registry = ArtifactRegistry::open(runtime, &cfg.artifacts_dir)?;
    let exec = registry.load("train_step")?;
    let mut params = init_params(&meta, cfg.seed);
    let lr = HostTensor::scalar(cfg.lr);
    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let (x, onehot) = synthetic_batch(&meta, step, cfg.seed);
        let mut inputs = Vec::new();
        for (w, b) in &params {
            inputs.push(w.to_literal()?);
            inputs.push(b.to_literal()?);
        }
        inputs.push(x.to_literal()?);
        inputs.push(onehot.to_literal()?);
        inputs.push(lr.to_literal()?);
        let outs = exec.run(&inputs)?;
        losses.push(HostTensor::from_literal(&outs[0])?.data[0]);
        for (li, p) in params.iter_mut().enumerate() {
            p.0 = HostTensor::from_literal(&outs[1 + 2 * li])?;
            p.1 = HostTensor::from_literal(&outs[2 + 2 * li])?;
        }
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        ArtifactRegistry::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn synthetic_batch_deterministic_and_onehot() {
        let meta = ModelMeta {
            batch: 8,
            classes: 4,
            layer_dims: vec![(16, 8), (8, 4)],
        };
        let (x1, o1) = synthetic_batch(&meta, 3, 42);
        let (x2, o2) = synthetic_batch(&meta, 3, 42);
        assert_eq!(x1, x2);
        assert_eq!(o1, o2);
        for r in 0..meta.batch {
            let row = &o1.data[r * meta.classes..(r + 1) * meta.classes];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        let (x3, _) = synthetic_batch(&meta, 4, 42);
        assert_ne!(x1, x3, "different steps differ");
    }

    #[test]
    fn init_params_shapes() {
        let meta = ModelMeta {
            batch: 8,
            classes: 4,
            layer_dims: vec![(16, 8), (8, 4)],
        };
        let p = init_params(&meta, 1);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].0.dims, vec![16, 8]);
        assert_eq!(p[1].1.dims, vec![4]);
    }

    /// Full distributed-vs-oracle equivalence on 2 devices. Requires
    /// `make artifacts` to have run.
    #[test]
    fn distributed_matches_oracle() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let meta = ModelMeta::load(&ArtifactRegistry::default_dir()).unwrap();
        let plan = MlpPlan {
            layer_dev: (0..meta.n_layers()).map(|i| i % 2).collect(),
            loss_dev: (meta.n_layers() - 1) % 2,
            n_devices: 2,
        };
        let cfg = TrainConfig {
            steps: 5,
            ..Default::default()
        };
        let dist = train_distributed(&plan, &cfg).unwrap();
        let oracle = train_oracle(&cfg).unwrap();
        assert_eq!(dist.losses.len(), oracle.len());
        for (s, (a, b)) in dist.losses.iter().zip(&oracle).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                "step {s}: dist {a} vs oracle {b}"
            );
        }
    }

    /// Loss must trend downward over a few dozen steps.
    #[test]
    fn training_learns() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let meta = ModelMeta::load(&ArtifactRegistry::default_dir()).unwrap();
        let plan = MlpPlan::single(meta.n_layers());
        let cfg = TrainConfig {
            steps: 40,
            lr: 0.1,
            ..Default::default()
        };
        let r = train_distributed(&plan, &cfg).unwrap();
        let head: f32 = r.losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = r.losses[r.losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(tail < head * 0.9, "no learning: {head} -> {tail}");
    }
}
