//! Execution plan: map a graph placement onto the MLP artifact pipeline.
//!
//! The MLP module graph (`models::mlp`) names its modules `layer{i}` and
//! `loss`; the plan extracts each module's device from a [`Placement`]
//! (forward, backward, and parameters share the module's device — the
//! paper's fwd/bwd co-placement, which our optimizer guarantees via the
//! shared co-placement group).

use crate::error::BaechiError;
use crate::graph::OpGraph;
use crate::placer::Placement;

/// Device assignment for the MLP pipeline stages.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpPlan {
    /// Device index per layer (params + fwd + bwd).
    pub layer_dev: Vec<usize>,
    /// Device of the loss module (loss_fwd + loss_bwd).
    pub loss_dev: usize,
    pub n_devices: usize,
}

impl MlpPlan {
    /// Derive the plan from a placement of the `models::mlp` graph.
    pub fn from_placement(
        graph: &OpGraph,
        placement: &Placement,
        n_devices: usize,
        n_layers: usize,
    ) -> crate::Result<MlpPlan> {
        let dev_of_prefix = |prefix: &str| -> crate::Result<usize> {
            let node = graph
                .iter_nodes()
                .find(|n| n.name.starts_with(prefix))
                .ok_or_else(|| BaechiError::invalid(format!("no node with prefix '{prefix}'")))?;
            placement
                .try_device(node.id)
                .map(|d| d.0)
                .ok_or_else(|| {
                    BaechiError::invalid(format!(
                        "node '{}' missing from placement '{}'",
                        node.name, placement.algorithm
                    ))
                })
        };
        let mut layer_dev = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            layer_dev.push(dev_of_prefix(&format!("layer{i}/fwd"))?);
        }
        let loss_dev = dev_of_prefix("loss/fwd")?;
        Ok(MlpPlan {
            layer_dev,
            loss_dev,
            n_devices,
        })
    }

    /// All-on-one-device plan (oracle / single-GPU baseline).
    pub fn single(n_layers: usize) -> MlpPlan {
        MlpPlan {
            layer_dev: vec![0; n_layers],
            loss_dev: 0,
            n_devices: 1,
        }
    }

    /// Number of cross-device tensor hops per training step.
    pub fn cross_device_hops(&self) -> usize {
        let mut hops = 0;
        // forward chain + dy backward chain
        for w in self.layer_dev.windows(2) {
            if w[0] != w[1] {
                hops += 2; // activation fwd + gradient bwd
            }
        }
        if self.layer_dev.last() != Some(&self.loss_dev) {
            hops += 2; // logits + dy
        }
        hops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{mlp, MlpConfig};
    use crate::placer::Placer;
    use crate::profile::{Cluster, CommModel};

    #[test]
    fn derives_from_metf_placement() {
        let cfg = MlpConfig::default();
        let g = mlp(&cfg);
        let cluster = Cluster::homogeneous(2, 64 << 30, CommModel::pcie_via_host());
        let p = crate::placer::metf::MEtf.place(&g, &cluster).unwrap();
        let plan = MlpPlan::from_placement(&g, &p, 2, 4).unwrap();
        assert_eq!(plan.layer_dev.len(), 4);
        assert!(plan.layer_dev.iter().all(|&d| d < 2));
        assert!(plan.loss_dev < 2);
    }

    #[test]
    fn hops_counted() {
        let plan = MlpPlan {
            layer_dev: vec![0, 0, 1, 1],
            loss_dev: 1,
            n_devices: 2,
        };
        assert_eq!(plan.cross_device_hops(), 2);
        let single = MlpPlan::single(4);
        assert_eq!(single.cross_device_hops(), 0);
    }
}
