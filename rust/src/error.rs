//! The typed error surface of the crate.
//!
//! Every public fallible API returns [`crate::Result`], whose error type
//! is [`BaechiError`], so callers branch on failure modes — placement
//! OOM vs unknown placer vs malformed request — instead of parsing
//! strings:
//!
//! ```no_run
//! use baechi::engine::{PlacementEngine, PlacementRequest};
//! use baechi::profile::{Cluster, CommModel};
//! use baechi::BaechiError;
//!
//! let engine = PlacementEngine::builder()
//!     .cluster(Cluster::homogeneous(4, 8 << 30, CommModel::pcie_via_host()))
//!     .build()?;
//! let graph = baechi::models::linreg::linreg_graph();
//! match engine.place(&PlacementRequest::new(graph, "m-sct")) {
//!     Ok(resp) => println!("{} devices", resp.devices_used),
//!     Err(BaechiError::Oom { op, best_device, deficit }) => {
//!         eprintln!("{op} needs {deficit} more bytes (closest: {best_device:?})")
//!     }
//!     Err(e) => eprintln!("{e}"),
//! }
//! # Ok::<(), BaechiError>(())
//! ```

use crate::graph::DeviceId;
use crate::util::json::JsonError;

/// Structured failure of any Baechi operation.
#[derive(Debug, Clone, PartialEq)]
pub enum BaechiError {
    /// Placement-time OOM: no device can host `op`. `best_device` is the
    /// device that came closest and `deficit` how many bytes it fell
    /// short (0 when no device was even a candidate).
    Oom {
        op: String,
        best_device: Option<DeviceId>,
        deficit: u64,
    },
    /// The graph to place contains a cycle.
    Cyclic,
    /// Placer name absent from the [`crate::engine::PlacerRegistry`].
    UnknownPlacer { name: String, known: Vec<String> },
    /// Malformed request, configuration, or CLI input.
    InvalidRequest(String),
    /// A placer ran to completion without finding a feasible placement
    /// (e.g. the RL baseline exhausting its episode budget).
    Infeasible(String),
    /// LP substrate failure (shape mismatch, non-PD normal matrix, …).
    Lp(String),
    /// JSON parse failure.
    Json(JsonError),
    /// Filesystem failure, with path context where available.
    Io(String),
    /// Runtime/executor failure (PJRT backend, device worker threads).
    Runtime(String),
    /// A serving deadline elapsed before the request was placed
    /// ([`crate::serve::PlacementService`]). `waited` is how long the
    /// request sat, in seconds.
    DeadlineExceeded { waited: f64 },
    /// The placement service's bounded request queue is full
    /// (backpressure signal from `try_submit`).
    Saturated { capacity: usize },
}

impl BaechiError {
    pub fn invalid(msg: impl Into<String>) -> BaechiError {
        BaechiError::InvalidRequest(msg.into())
    }

    pub fn runtime(msg: impl Into<String>) -> BaechiError {
        BaechiError::Runtime(msg.into())
    }

    pub fn io(msg: impl Into<String>) -> BaechiError {
        BaechiError::Io(msg.into())
    }

    pub fn lp(msg: impl Into<String>) -> BaechiError {
        BaechiError::Lp(msg.into())
    }
}

impl std::fmt::Display for BaechiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BaechiError::Oom {
                op,
                best_device,
                deficit,
            } => {
                write!(f, "out of memory: operator {op} does not fit on any device")?;
                if let Some(dev) = best_device {
                    write!(f, " (closest: {dev}, {deficit} bytes short)")?;
                }
                Ok(())
            }
            BaechiError::Cyclic => write!(f, "graph is not a DAG"),
            BaechiError::UnknownPlacer { name, known } => {
                write!(f, "unknown placer '{name}' (known: {})", known.join("|"))
            }
            BaechiError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            BaechiError::Infeasible(msg) => write!(f, "no feasible placement: {msg}"),
            BaechiError::Lp(msg) => write!(f, "lp: {msg}"),
            BaechiError::Json(e) => write!(f, "{e}"),
            BaechiError::Io(msg) => write!(f, "io: {msg}"),
            BaechiError::Runtime(msg) => write!(f, "runtime: {msg}"),
            BaechiError::DeadlineExceeded { waited } => {
                write!(f, "deadline exceeded: request waited {waited:.3}s unserved")
            }
            BaechiError::Saturated { capacity } => {
                write!(f, "service saturated: request queue full at capacity {capacity}")
            }
        }
    }
}

impl std::error::Error for BaechiError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BaechiError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<JsonError> for BaechiError {
    fn from(e: JsonError) -> BaechiError {
        BaechiError::Json(e)
    }
}

impl From<std::io::Error> for BaechiError {
    fn from(e: std::io::Error) -> BaechiError {
        BaechiError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_oom_phrase() {
        let e = BaechiError::Oom {
            op: "conv5".into(),
            best_device: Some(DeviceId(2)),
            deficit: 1024,
        };
        let s = e.to_string();
        assert!(s.contains("out of memory"), "{s}");
        assert!(s.contains("gpu2"), "{s}");
        assert!(s.contains("1024"), "{s}");
    }

    #[test]
    fn unknown_placer_lists_known() {
        let e = BaechiError::UnknownPlacer {
            name: "nope".into(),
            known: vec!["m-etf".into(), "m-sct".into()],
        };
        assert!(e.to_string().contains("m-etf|m-sct"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: BaechiError = io.into();
        assert!(matches!(e, BaechiError::Io(_)));
    }
}
