//! Graph optimizer (paper §3.1): co-placement, operator fusion, and
//! forward-operator-based placement, producing the reduced meta-graph
//! the placement algorithms run on.
//!
//! Pipeline (all stages optional, mirroring Table 6's ablation):
//!
//! 1. [`coplacement::apply_coplacement`] labels single-consumer chains and
//!    backward ops (§3.1.2).
//! 2. [`fusion::fuse`] contracts same-group edges under the cycle-safe
//!    degree rule (§3.1.3).
//! 3. Forward-only projection drops backward nodes from the placement
//!    graph when memory suffices, folding their memory into their forward
//!    anchor; after placement they inherit the anchor's device (§3.1.3).
//!
//! [`expand_placement`] maps a meta-graph placement back onto the full
//! original operator graph.

pub mod coplacement;
pub mod fusion;

use crate::graph::{DeviceId, NodeId, OpGraph};
use std::collections::BTreeMap;

/// Optimizer configuration (Table 6 toggles these).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptConfig {
    /// Apply the co-placement heuristics (§3.1.2).
    pub coplacement: bool,
    /// Apply cycle-safe operator fusion (§3.1.3).
    pub fusion: bool,
    /// Place only forward operators (valid when memory is sufficient).
    pub forward_only: bool,
    /// Latency-equivalent bytes (`latency × bandwidth` of the comm
    /// model) used to pad multi-tensor fused edges so placement-time
    /// comm estimates match the per-tensor costs the ES charges.
    pub latency_equiv_bytes: u64,
}

impl Default for OptConfig {
    fn default() -> OptConfig {
        OptConfig {
            coplacement: true,
            fusion: true,
            forward_only: false,
            latency_equiv_bytes: 0,
        }
    }
}

impl OptConfig {
    /// Everything off — the "Un-Optimized" column of Table 6.
    pub fn none() -> OptConfig {
        OptConfig {
            coplacement: false,
            fusion: false,
            forward_only: false,
            latency_equiv_bytes: 0,
        }
    }

    /// Everything on (sufficient-memory regime).
    pub fn full() -> OptConfig {
        OptConfig {
            coplacement: true,
            fusion: true,
            forward_only: true,
            latency_equiv_bytes: 0,
        }
    }
}

/// Optimizer output: the graph to place plus the bookkeeping needed to
/// expand a placement back to the original graph.
pub struct Optimized {
    /// The (possibly fused, possibly forward-only) graph to place.
    pub graph: OpGraph,
    /// Original node slot → node in `graph` that decides its device.
    pub anchor: Vec<Option<NodeId>>,
    pub stats: OptStats,
}

/// Reduction statistics (Table 6 columns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OptStats {
    pub original_ops: usize,
    pub placed_ops: usize,
    pub fused_edges: usize,
    pub coplacement_labels: usize,
}

/// Run the optimizer pipeline.
pub fn optimize(original: &OpGraph, cfg: &OptConfig) -> Optimized {
    let mut work = original.clone();
    let mut stats = OptStats {
        original_ops: original.len(),
        ..Default::default()
    };

    if cfg.coplacement {
        let s = coplacement::apply_coplacement(&mut work);
        stats.coplacement_labels = s.chain_labeled + s.bwd_labeled;
    }

    // Fusion (uses colocation groups even when coplacement is off —
    // TF colocation constraints always hold, §3.1.1).
    let (mut graph, mut anchor) = if cfg.fusion {
        let fused =
            fusion::fuse_with_latency_equiv(&work, fusion::same_group, cfg.latency_equiv_bytes);
        stats.fused_edges = fused.fused_edges;
        (fused.graph, fused.meta_of)
    } else {
        // Identity mapping.
        let anchor: Vec<Option<NodeId>> = (0..work.capacity())
            .map(|i| {
                if work.is_alive(NodeId(i)) {
                    Some(NodeId(i))
                } else {
                    None
                }
            })
            .collect();
        (work.clone(), anchor)
    };

    if cfg.forward_only {
        let (fwd_graph, remap) = forward_projection(&graph);
        // Compose: original → meta → forward anchor.
        for slot in anchor.iter_mut() {
            if let Some(meta) = *slot {
                *slot = remap[meta.0];
            }
        }
        graph = fwd_graph;
    }

    stats.placed_ops = graph.len();
    Optimized {
        graph,
        anchor,
        stats,
    }
}

/// Project out backward nodes. Backward memory is folded into the anchor
/// node so the placement-time memory ledger still covers it. Returns the
/// forward graph and a map `meta node → forward node`.
///
/// The projected graph reuses the input's node ids for forward nodes
/// (backward slots become tombstones), so edges can be copied directly.
fn forward_projection(graph: &OpGraph) -> (OpGraph, Vec<Option<NodeId>>) {
    let cap = graph.capacity();
    let mut remap: Vec<Option<NodeId>> = vec![None; cap];
    let mut out = OpGraph::new(&graph.name);
    // Recreate all slots to preserve ids; tombstone dead + backward.
    for i in 0..cap {
        let id = NodeId(i);
        let new_id = out.add_node("tomb", crate::graph::OpKind::Generic(0));
        debug_assert_eq!(new_id.0, i);
        if graph.is_alive(id) && !graph.node(id).is_backward {
            *out.node_mut(new_id) = crate::graph::OpNode {
                id: new_id,
                ..graph.node(id).clone()
            };
            remap[i] = Some(new_id);
        } else {
            out.remove_node(new_id);
        }
    }
    // Forward–forward edges survive.
    for e in graph.edges() {
        if remap[e.src.0].is_some() && remap[e.dst.0].is_some() {
            out.add_edge(e.src, e.dst, e.bytes);
        }
    }
    // Anchor backward nodes and fold their memory into the anchor.
    for i in 0..cap {
        let id = NodeId(i);
        if !graph.is_alive(id) || !graph.node(id).is_backward {
            continue;
        }
        let n = graph.node(id);
        // Prefer the explicit forward link; otherwise a colocation-group
        // sibling (ApplyGrad anchors to its Variable, §3.1.1); otherwise
        // a forward predecessor.
        let target = n
            .forward_of
            .filter(|f| remap[f.0].is_some())
            .or_else(|| {
                n.colocation_group.as_ref().and_then(|grp| {
                    graph
                        .iter_nodes()
                        .find(|m| !m.is_backward && m.colocation_group.as_ref() == Some(grp))
                        .map(|m| m.id)
                })
            })
            .or_else(|| {
                graph
                    .predecessors(id)
                    .iter()
                    .map(|&(p, _)| p)
                    .find(|p| remap[p.0].is_some())
            });
        if let Some(t) = target.and_then(|t| remap[t.0]) {
            remap[i] = Some(t);
            let mem = n.mem;
            let anchor_node = out.node_mut(t);
            anchor_node.mem = anchor_node.mem.merge(&mem);
        } else {
            // No forward anchor found (pathological); keep the node.
            let keep = out.add_node("orphan_bwd", n.kind.clone());
            *out.node_mut(keep) = crate::graph::OpNode {
                id: keep,
                ..n.clone()
            };
            remap[i] = Some(keep);
        }
    }
    debug_assert!(out.is_acyclic());
    (out, remap)
}

/// Expand a meta-graph placement to the original operator graph.
pub fn expand_placement(
    original: &OpGraph,
    opt: &Optimized,
    meta_placement: &BTreeMap<NodeId, DeviceId>,
) -> BTreeMap<NodeId, DeviceId> {
    let mut full = BTreeMap::new();
    for id in original.node_ids() {
        let anchor = opt.anchor[id.0].expect("every live op has an anchor");
        let dev = *meta_placement
            .get(&anchor)
            .unwrap_or_else(|| panic!("anchor {anchor} unplaced for op {id}"));
        full.insert(id, dev);
    }
    full
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::transformer::{transformer, TransformerConfig};

    #[test]
    fn full_pipeline_reduces_transformer() {
        let g = transformer(TransformerConfig::paper(64));
        let opt = optimize(&g, &OptConfig::full());
        assert!(opt.graph.is_acyclic());
        assert!(
            opt.stats.placed_ops * 3 < opt.stats.original_ops,
            "{} -> {}",
            opt.stats.original_ops,
            opt.stats.placed_ops
        );
        // Forward-only: no backward nodes remain.
        assert!(opt.graph.iter_nodes().all(|n| !n.is_backward));
        // Every original op has an anchor in the placed graph.
        for id in g.node_ids() {
            let a = opt.anchor[id.0].expect("anchor");
            assert!(opt.graph.is_alive(a));
        }
    }

    #[test]
    fn none_config_is_identity() {
        let g = transformer(TransformerConfig::paper(64));
        let opt = optimize(&g, &OptConfig::none());
        assert_eq!(opt.graph.len(), g.len());
        assert_eq!(opt.stats.fused_edges, 0);
        for id in g.node_ids() {
            assert_eq!(opt.anchor[id.0], Some(id));
        }
    }

    #[test]
    fn memory_is_conserved_under_forward_only() {
        // Folding backward memory into anchors must not lose bytes:
        // total placed memory ≥ total original permanent memory.
        let g = transformer(TransformerConfig::paper(64));
        let opt = optimize(&g, &OptConfig::full());
        let orig_mem = g.total_permanent_memory();
        let placed_mem = opt.graph.total_permanent_memory();
        assert!(
            placed_mem >= orig_mem,
            "placed {placed_mem} < original {orig_mem}"
        );
    }

    #[test]
    fn expand_placement_covers_all_ops() {
        let g = transformer(TransformerConfig::paper(64));
        let opt = optimize(&g, &OptConfig::full());
        let mut meta_placement = BTreeMap::new();
        for (i, id) in opt.graph.node_ids().enumerate() {
            meta_placement.insert(id, DeviceId(i % 4));
        }
        let full = expand_placement(&g, &opt, &meta_placement);
        assert_eq!(full.len(), g.len());
        // fwd/bwd matching: when fused into the same meta node, devices
        // must agree.
        for n in g.iter_nodes().filter(|n| n.is_backward) {
            if let Some(f) = n.forward_of {
                if opt.anchor[n.id.0] == opt.anchor[f.0] {
                    assert_eq!(full[&n.id], full[&f]);
                }
            }
        }
    }

    #[test]
    fn fusion_without_coplacement_uses_colocation_only() {
        let g = crate::models::linreg::linreg_graph();
        let opt = optimize(
            &g,
            &OptConfig {
                coplacement: false,
                fusion: true,
                forward_only: false,
                latency_equiv_bytes: 0,
            },
        );
        // linreg has 2 colocation pairs; only directly-connected pairs
        // fuse: {Step, UpdateStep} (edge) and {Weight, ApplyGrad} (no
        // direct edge → cannot fuse). 7 ops → 6.
        assert_eq!(opt.graph.len(), 6);
    }
}
