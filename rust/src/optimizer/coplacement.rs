//! Co-placement heuristics (paper §3.1.2).
//!
//! Two rules:
//! 1. **Single-consumer chains** — "if the output of an operator is only
//!    used by its next operator, we place both operators on the same
//!    device" (the `tf.tensordot` example of Fig. 3). We express this by
//!    assigning both ops the same co-placement group label.
//! 2. **Forward/backward matching** — each backward op joins its matched
//!    forward op's group.
//!
//! Labels already assigned by the model generators are respected; the
//! heuristic only adds labels, never rewrites existing ones (rewriting
//! could merge unrelated groups through a shared neighbor).

use crate::graph::OpGraph;

/// Statistics from a co-placement pass.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct CoplacementStats {
    /// Ops newly labeled by the single-consumer rule.
    pub chain_labeled: usize,
    /// Backward ops newly labeled via their forward match.
    pub bwd_labeled: usize,
}

/// Apply both heuristics in place.
pub fn apply_coplacement(graph: &mut OpGraph) -> CoplacementStats {
    let mut stats = CoplacementStats::default();

    // Rule 1: single-consumer chains, walked in topological order so a
    // chain a→b→c acquires one shared label.
    let order = graph
        .topo_order()
        .expect("coplacement requires acyclic graph");
    for &u in &order {
        if graph.out_degree(u) != 1 {
            continue;
        }
        let (v, _) = graph.successors(u)[0];
        let u_grp = graph.node(u).coplacement_group.clone();
        let v_grp = graph.node(v).coplacement_group.clone();
        match (u_grp, v_grp) {
            (Some(g), None) => {
                // extend u's group forward onto its only consumer
                graph.node_mut(v).coplacement_group = Some(g);
                stats.chain_labeled += 1;
            }
            (None, Some(g)) => {
                graph.node_mut(u).coplacement_group = Some(g);
                stats.chain_labeled += 1;
            }
            (None, None) => {
                let label = format!("chain/{}", u.0);
                graph.node_mut(u).coplacement_group = Some(label.clone());
                graph.node_mut(v).coplacement_group = Some(label);
                stats.chain_labeled += 2;
            }
            (Some(_), Some(_)) => {} // both already grouped: leave as-is
        }
    }

    // Rule 2: backward ops join their forward op's group.
    let ids: Vec<_> = graph.node_ids().collect();
    for id in ids {
        let n = graph.node(id);
        if !n.is_backward || n.coplacement_group.is_some() {
            continue;
        }
        if let Some(f) = n.forward_of {
            if let Some(g) = graph.node(f).coplacement_group.clone() {
                graph.node_mut(id).coplacement_group = Some(g);
                stats.bwd_labeled += 1;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpGraph, OpKind};

    #[test]
    fn tensordot_pattern_grouped() {
        // Fig. 3: op_in → Transpose → Reshape chain, with perm/Shape
        // constants feeding in. Single-consumer rule groups the chain.
        let mut g = OpGraph::new("tensordot");
        let op_in = g.add_node("op_in", OpKind::MatMul);
        let perm = g.add_node("perm", OpKind::Shape);
        let transpose = g.add_node("transpose", OpKind::Shape);
        let shape = g.add_node("shape", OpKind::Shape);
        let reshape = g.add_node("reshape", OpKind::Shape);
        g.add_edge(op_in, transpose, 100);
        g.add_edge(perm, transpose, 4);
        g.add_edge(transpose, reshape, 100);
        g.add_edge(shape, reshape, 4);
        let stats = apply_coplacement(&mut g);
        assert!(stats.chain_labeled > 0);
        // op_in, perm, transpose, reshape, shape should share one group
        // through the chain rule (each feeds a single consumer).
        let grp = g.node(transpose).coplacement_group.clone().unwrap();
        for id in [op_in, perm, shape, reshape] {
            assert_eq!(
                g.node(id).coplacement_group.as_ref(),
                Some(&grp),
                "node {} not grouped",
                g.node(id).name
            );
        }
    }

    #[test]
    fn fanout_not_grouped() {
        let mut g = OpGraph::new("fan");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        apply_coplacement(&mut g);
        // `a` has two consumers → no chain label for a.
        assert!(g.node(a).coplacement_group.is_none());
    }

    #[test]
    fn bwd_joins_fwd_group() {
        let mut g = OpGraph::new("t");
        let f1 = g.add_node("f1", OpKind::MatMul);
        let f2 = g.add_node("f2", OpKind::MatMul);
        let b1 = g.add_node("b1", OpKind::MatMul);
        g.add_edge(f1, f2, 1);
        g.add_edge(f2, b1, 1);
        g.node_mut(b1).is_backward = true;
        g.node_mut(b1).forward_of = Some(f1);
        // pre-label fwd chain
        g.node_mut(f1).coplacement_group = Some("L".into());
        g.node_mut(f2).coplacement_group = Some("L".into());
        let stats = apply_coplacement(&mut g);
        assert_eq!(g.node(b1).coplacement_group.as_deref(), Some("L"));
        assert!(stats.bwd_labeled <= 1); // may be chain-labeled first
    }

    #[test]
    fn existing_labels_not_rewritten() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.node_mut(a).coplacement_group = Some("A".into());
        g.node_mut(b).coplacement_group = Some("B".into());
        apply_coplacement(&mut g);
        assert_eq!(g.node(a).coplacement_group.as_deref(), Some("A"));
        assert_eq!(g.node(b).coplacement_group.as_deref(), Some("B"));
    }
}
