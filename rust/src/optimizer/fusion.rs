//! Cycle-safe operator fusion (paper §3.1.3).
//!
//! Baechi fuses directly-connected operators that share a colocation or
//! co-placement group. Merging `src → dst` creates a cycle iff another
//! `src ⇝ dst` path exists; checking that per edge is unscalable, so the
//! paper fuses only when `out_degree(src) ≤ 1` **or** `in_degree(dst) ≤ 1`
//! (Figures 4e/4f) — a *necessary* condition for an alternative path is
//! out-degree ≥ 2 at the source and in-degree ≥ 2 at the destination.
//!
//! Fusion runs to a fixpoint: contracting an edge lowers degrees and can
//! enable further fusions (e.g. a chain collapses completely).

use crate::graph::{MemorySpec, NodeId, OpGraph, OpNode};
use std::collections::BTreeSet;

/// Union-find over node slots.
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Dsu {
        Dsu {
            parent: (0..n).collect(),
        }
    }
    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let root = self.find(self.parent[x]);
            self.parent[x] = root;
        }
        self.parent[x]
    }
    fn union_into(&mut self, child: usize, root: usize) {
        let c = self.find(child);
        let r = self.find(root);
        if c != r {
            self.parent[c] = r;
        }
    }
}

/// Result of fusing a graph.
pub struct Fused {
    /// The fused meta-operator graph.
    pub graph: OpGraph,
    /// Map original node slot → meta node id (None for dead slots).
    pub meta_of: Vec<Option<NodeId>>,
    /// Number of edge contractions performed.
    pub fused_edges: usize,
}

/// Whether two ops belong to the same fusion group (same colocation
/// constraint group or same co-placement group).
pub fn same_group(a: &OpNode, b: &OpNode) -> bool {
    let colo = match (&a.colocation_group, &b.colocation_group) {
        (Some(x), Some(y)) => x == y,
        _ => false,
    };
    let copl = match (&a.coplacement_group, &b.coplacement_group) {
        (Some(x), Some(y)) => x == y,
        _ => false,
    };
    colo || copl
}

/// Fuse the graph to a fixpoint under the cycle-safe rule. `eligible`
/// decides whether a directly-connected pair may fuse (on top of the
/// degree rule).
pub fn fuse(graph: &OpGraph, eligible: impl Fn(&OpNode, &OpNode) -> bool) -> Fused {
    fuse_with_latency_equiv(graph, eligible, 0)
}

/// Like [`fuse`], additionally padding each merged meta-edge with
/// `latency_equiv_bytes` per extra constituent tensor. With
/// `latency_equiv = latency × bandwidth`, the linear comm model then
/// prices a meta edge at exactly `count × latency + Σbytes / bandwidth`
/// — the cost the execution simulator charges when it moves every
/// constituent tensor individually. Without this, placement-time
/// schedules systematically underestimate scattering penalties on
/// latency-bound interconnects.
pub fn fuse_with_latency_equiv(
    graph: &OpGraph,
    eligible: impl Fn(&OpNode, &OpNode) -> bool,
    latency_equiv_bytes: u64,
) -> Fused {
    let cap = graph.capacity();
    let mut dsu = Dsu::new(cap);
    // Live adjacency over representatives, with per-edge max bytes.
    let mut outs: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); cap];
    let mut ins: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); cap];
    // Parallel edges between (future) meta nodes each carry their own
    // tensor at runtime — track (summed bytes, tensor count) per pair.
    let mut bytes: std::collections::BTreeMap<(usize, usize), (u64, u32)> = Default::default();
    let mut alive: Vec<bool> = (0..cap).map(|i| graph.is_alive(NodeId(i))).collect();
    for e in graph.edges() {
        outs[e.src.0].insert(e.dst.0);
        ins[e.dst.0].insert(e.src.0);
        let slot = bytes.entry((e.src.0, e.dst.0)).or_insert((0, 0));
        slot.0 += e.bytes;
        slot.1 += 1;
    }

    let mut fused_edges = 0usize;
    // Worklist of candidate edges.
    let mut work: Vec<(usize, usize)> = bytes.keys().copied().collect();
    while let Some((u0, v0)) = work.pop() {
        let u = dsu.find(u0);
        let v = dsu.find(v0);
        if u == v || !alive[u] || !alive[v] || !outs[u].contains(&v) {
            continue;
        }
        // Group eligibility is defined on representative *members*; we use
        // the original nodes' annotations (groups never change under
        // fusion — a meta node inherits its members' groups).
        if !eligible(graph.node(NodeId(u0)), graph.node(NodeId(v0))) {
            continue;
        }
        // Cycle-safe degree rule on the *current* contracted graph.
        if outs[u].len() > 1 && ins[v].len() > 1 {
            continue;
        }
        // Contract v into u.
        fused_edges += 1;
        alive[v] = false;
        dsu.union_into(v, u);
        outs[u].remove(&v);
        ins[v].remove(&u);
        bytes.remove(&(u, v));
        // Redirect v's out-edges to u.
        let v_outs: Vec<usize> = outs[v].iter().copied().collect();
        for w in v_outs {
            ins[w].remove(&v);
            let (b, c) = bytes.remove(&(v, w)).unwrap_or((0, 0));
            if w != u {
                outs[u].insert(w);
                ins[w].insert(u);
                let slot = bytes.entry((u, w)).or_insert((0, 0));
                slot.0 += b;
                slot.1 += c;
                work.push((u, w));
            }
        }
        outs[v].clear();
        // Redirect v's in-edges to u.
        let v_ins: Vec<usize> = ins[v].iter().copied().collect();
        for w in v_ins {
            outs[w].remove(&v);
            let (b, c) = bytes.remove(&(w, v)).unwrap_or((0, 0));
            if w != u {
                outs[w].insert(u);
                ins[u].insert(w);
                let slot = bytes.entry((w, u)).or_insert((0, 0));
                slot.0 += b;
                slot.1 += c;
                work.push((w, u));
            }
        }
        ins[v].clear();
        // New degree situation at u may enable more fusions.
        for &w in &outs[u] {
            work.push((u, w));
        }
        for &w in &ins[u] {
            work.push((w, u));
        }
    }

    // Build the meta graph: one node per live representative.
    let mut meta = OpGraph::new(&graph.name);
    let mut meta_of: Vec<Option<NodeId>> = vec![None; cap];
    // Group members per representative for annotation merging.
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); cap];
    for i in 0..cap {
        if graph.is_alive(NodeId(i)) {
            members[dsu.find(i)].push(i);
        }
    }
    // Colocation groups whose members were fused into the same meta node
    // transitively merge (everything must land on one device): union the
    // labels so every affected meta node carries one canonical group.
    fn colo_root(map: &mut std::collections::BTreeMap<String, String>, g: &str) -> String {
        let parent = map
            .entry(g.to_string())
            .or_insert_with(|| g.to_string())
            .clone();
        if parent == g {
            return parent;
        }
        let root = colo_root(map, &parent);
        map.insert(g.to_string(), root.clone());
        root
    }
    let mut colo_union: std::collections::BTreeMap<String, String> = Default::default();
    for rep in 0..cap {
        if !alive[rep] || members[rep].is_empty() {
            continue;
        }
        let mut first_grp: Option<String> = None;
        for &m in &members[rep] {
            if let Some(g) = &graph.node(NodeId(m)).colocation_group {
                let root = colo_root(&mut colo_union, g);
                match &first_grp {
                    None => first_grp = Some(root),
                    Some(f) => {
                        let froot = colo_root(&mut colo_union, &f.clone());
                        if froot != root {
                            colo_union.insert(root, froot);
                        }
                    }
                }
            }
        }
    }

    for rep in 0..cap {
        if !alive[rep] || members[rep].is_empty() {
            continue;
        }
        let first = graph.node(NodeId(members[rep][0]));
        let id = meta.add_node(&first.name, first.kind.clone());
        let mut compute = 0.0;
        let mut mem = MemorySpec::default();
        let mut is_backward = true;
        let mut colo = None;
        let mut copl = None;
        for &m in &members[rep] {
            let n = graph.node(NodeId(m));
            compute += n.compute;
            mem = mem.merge(&n.mem);
            is_backward &= n.is_backward;
            if colo.is_none() {
                colo = n
                    .colocation_group
                    .as_ref()
                    .map(|g| colo_root(&mut colo_union, g));
            }
            if copl.is_none() {
                copl = n.coplacement_group.clone();
            }
        }
        {
            let mn = meta.node_mut(id);
            mn.compute = compute;
            mn.mem = mem;
            mn.is_backward = is_backward;
            mn.colocation_group = colo;
            mn.coplacement_group = copl;
            mn.fused_from = members[rep].iter().map(|&m| NodeId(m)).collect();
        }
        for &m in &members[rep] {
            meta_of[m] = Some(id);
        }
    }
    // Meta node output bytes: max outgoing edge payload. Multi-tensor
    // meta edges get latency-equivalent padding (see fn docs).
    for (&(u, v), &(b, c)) in &bytes {
        let (mu, mv) = (meta_of[u].unwrap(), meta_of[v].unwrap());
        if mu != mv {
            let eff = b + latency_equiv_bytes * c.saturating_sub(1) as u64;
            meta.add_edge(mu, mv, eff);
            let n = meta.node_mut(mu);
            n.output_bytes = n.output_bytes.max(b);
            n.mem.output = n.mem.output.max(b);
        }
    }
    // Map forward_of through the contraction.
    let fwd_map: Vec<Option<NodeId>> = (0..cap)
        .map(|i| {
            if graph.is_alive(NodeId(i)) {
                graph.node(NodeId(i)).forward_of.and_then(|f| meta_of[f.0])
            } else {
                None
            }
        })
        .collect();
    for i in 0..cap {
        if let (Some(meta_id), Some(fwd_meta)) = (meta_of[i], fwd_map[i]) {
            if meta_id != fwd_meta && meta.node(meta_id).forward_of.is_none() {
                meta.node_mut(meta_id).forward_of = Some(fwd_meta);
            }
        }
    }

    debug_assert!(meta.is_acyclic(), "fusion created a cycle");
    Fused {
        graph: meta,
        meta_of,
        fused_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpGraph, OpKind};

    fn grouped(g: &mut OpGraph, id: NodeId, grp: &str) {
        g.node_mut(id).coplacement_group = Some(grp.to_string());
    }

    #[test]
    fn chain_collapses() {
        let mut g = OpGraph::new("chain");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.add_edge(b, c, 1);
        for (id, t) in [(a, 1.0), (b, 2.0), (c, 3.0)] {
            g.node_mut(id).compute = t;
        }
        for id in [a, b, c] {
            grouped(&mut g, id, "x");
        }
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 1);
        assert_eq!(f.fused_edges, 2);
        let meta = f.graph.iter_nodes().next().unwrap();
        assert!((meta.compute - 6.0).abs() < 1e-12);
        assert_eq!(meta.fused_from.len(), 3);
    }

    #[test]
    fn unsafe_diamond_edge_not_fused() {
        // a → b, a → c, b → d, c → d, plus direct a → d in group with d:
        // fusing a,d would create a cycle (paths via b and c). Degree rule
        // must reject (outdeg(a)=3 > 1, indeg(d)=3 > 1).
        let mut g = OpGraph::new("diamond");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        g.add_edge(a, d, 1);
        g.add_edge(b, d, 1);
        g.add_edge(c, d, 1);
        grouped(&mut g, a, "x");
        grouped(&mut g, d, "x");
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 4, "a–d must not fuse");
        assert!(f.graph.is_acyclic());
    }

    #[test]
    fn figure_4e_pattern_fuses() {
        // Fig 4e: src out-degree 1, dst in-degree 2 → safe.
        let mut g = OpGraph::new("4e");
        let p = g.add_node("p", OpKind::MatMul);
        let src = g.add_node("src", OpKind::MatMul);
        let dst = g.add_node("dst", OpKind::MatMul);
        g.add_edge(p, dst, 1);
        g.add_edge(src, dst, 1);
        grouped(&mut g, src, "x");
        grouped(&mut g, dst, "x");
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 2);
        assert!(f.graph.is_acyclic());
    }

    #[test]
    fn different_groups_do_not_fuse() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 1);
        grouped(&mut g, a, "x");
        grouped(&mut g, b, "y");
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 2);
        assert_eq!(f.fused_edges, 0);
    }

    #[test]
    fn colocation_groups_also_fuse() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::Variable);
        let b = g.add_node("b", OpKind::ApplyGrad);
        g.add_edge(a, b, 1);
        g.node_mut(a).colocation_group = Some("w".into());
        g.node_mut(b).colocation_group = Some("w".into());
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 1);
    }

    #[test]
    fn edges_redirected_with_bytes() {
        // a --(5)--> b(fuse with c) --(7)--> d ; a-b fuse? a not grouped.
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        g.add_edge(a, b, 5);
        g.add_edge(b, c, 3);
        g.add_edge(c, d, 7);
        grouped(&mut g, b, "x");
        grouped(&mut g, c, "x");
        let f = fuse(&g, same_group);
        assert_eq!(f.graph.len(), 3);
        let meta_b = f.meta_of[b.0].unwrap();
        assert_eq!(f.meta_of[c.0].unwrap(), meta_b);
        let ma = f.meta_of[a.0].unwrap();
        let md = f.meta_of[d.0].unwrap();
        assert_eq!(f.graph.edge_bytes(ma, meta_b), Some(5));
        assert_eq!(f.graph.edge_bytes(meta_b, md), Some(7));
    }

    #[test]
    fn fuses_model_scale_graph() {
        let g = crate::models::transformer::transformer(
            crate::models::transformer::TransformerConfig::paper(64),
        );
        let before = g.len();
        let f = fuse(&g, same_group);
        assert!(f.graph.is_acyclic());
        assert!(
            f.graph.len() * 2 < before,
            "{} -> {}",
            before,
            f.graph.len()
        );
        // Every live original node maps to a meta node.
        for id in g.node_ids() {
            assert!(f.meta_of[id.0].is_some());
        }
    }
}
