//! Graph coarsening: contract chains and co-placement groups into
//! super-ops.
//!
//! The contraction is organized in rounds over the current *quotient*
//! graph (original nodes merged by a union-find). In each round an edge
//! `U → V` between distinct components is contracted when the source
//! component has **exactly one** outgoing quotient edge, and either
//!
//! * **chain rule** — `V` also has exactly one incoming quotient edge
//!   (a true linear chain; fan-in/fan-out stays uncontracted so the
//!   coarse graph keeps the original parallelism), or
//! * **group rule** — `U` and `V` carry the same optimizer co-placement
//!   group ([`crate::optimizer::coplacement`]), which the placer would
//!   keep together anyway.
//!
//! **Cycle safety.** All edges selected in a round are contracted
//! simultaneously (any subset of them — the size/colocation guards may
//! drop some). Because every selected edge leaves a component with
//! quotient out-degree 1, the selected edges form a functional forest on
//! components: each tree contracts toward a single exit component `r`,
//! and only `r` can keep external out-edges. Any cycle through the
//! merged component would therefore have to both enter and leave through
//! paths that lift to a path in the original graph re-entering one of
//! the merged components — i.e. an original cycle, which a DAG does not
//! have. So contraction never creates a cycle (`debug_assert`ed, and
//! property-tested in `prop_invariants`).
//!
//! **Aggregation.** A super-op's compute and five-component memory are
//! the *component-wise sums* of its members (not [`MemorySpec::merge`],
//! which maxes transients — the sum guarantees that if a super-op fits a
//! device, re-placing all members there during refine also fits). A
//! coarse edge `A → B` carries, for every member `u ∈ A` with edges into
//! `B`, the **max** bytes over those edges (one physical transfer per
//! tensor per destination device, §4.2), summed over the distinct
//! sources `u`.

use crate::graph::csr::Csr;
use crate::graph::{MemorySpec, NodeId, OpGraph};

/// Knobs for the hierarchical coarsen→place→refine pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoarsenConfig {
    /// Master switch: disabled means the `hier` placer delegates to
    /// plain m-SCT (bit-identical, property-tested).
    pub enabled: bool,
    /// Maximum original ops folded into one super-op.
    pub max_members: usize,
    /// Contraction rounds (each round rebuilds the quotient degrees).
    pub rounds: usize,
    /// Contract linear chains (out-degree 1 → in-degree 1).
    pub fuse_chains: bool,
    /// Contract edges within one optimizer co-placement group.
    pub fuse_groups: bool,
}

impl Default for CoarsenConfig {
    fn default() -> CoarsenConfig {
        CoarsenConfig {
            enabled: true,
            max_members: 64,
            rounds: 4,
            fuse_chains: true,
            fuse_groups: true,
        }
    }
}

impl CoarsenConfig {
    /// Coarsening disabled: `hier` becomes plain m-SCT.
    pub fn off() -> CoarsenConfig {
        CoarsenConfig {
            enabled: false,
            ..CoarsenConfig::default()
        }
    }

    /// Enabled with a custom super-op size cap.
    pub fn with_max_members(max_members: usize) -> CoarsenConfig {
        CoarsenConfig {
            max_members: max_members.max(2),
            ..CoarsenConfig::default()
        }
    }
}

/// Result of coarsening: the coarse graph plus both directions of the
/// node mapping.
#[derive(Debug, Clone)]
pub struct Coarse {
    /// The coarse graph of super-ops.
    pub graph: OpGraph,
    /// Original node slot → coarse node (`None` for tombstoned slots).
    pub super_of: Vec<Option<NodeId>>,
    /// Coarse node → sorted original member ids.
    pub members: Vec<Vec<NodeId>>,
}

/// No coplacement label seen yet.
const LBL_NONE: i64 = -1;
/// Members carry conflicting coplacement labels.
const LBL_CONFLICT: i64 = -2;

struct Dsu {
    parent: Vec<usize>,
    size: Vec<usize>,
    /// Colocation label per root (`LBL_NONE` = none; conflicts are
    /// prevented by the union guard).
    colo: Vec<i64>,
    /// Coplacement label per root (`LBL_NONE` / `LBL_CONFLICT`).
    copl: Vec<i64>,
}

impl Dsu {
    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]]; // path halving
            x = self.parent[x];
        }
        x
    }
}

/// Contract `graph` under `cfg` (the `enabled` flag is the caller's
/// concern; this function always coarsens per the fuse flags).
pub fn coarsen(graph: &OpGraph, cfg: &CoarsenConfig) -> Coarse {
    let cap = graph.capacity();
    let csr = Csr::build(graph);
    let max_members = cfg.max_members.max(2);

    // Intern group labels so union guards compare integers.
    let mut label_ids: std::collections::BTreeMap<&str, i64> = std::collections::BTreeMap::new();
    let mut intern = |s: Option<&str>| -> i64 {
        match s {
            None => LBL_NONE,
            Some(s) => {
                let next = label_ids.len() as i64;
                *label_ids.entry(s).or_insert(next)
            }
        }
    };
    let mut dsu = Dsu {
        parent: (0..cap).collect(),
        size: vec![1; cap],
        colo: vec![LBL_NONE; cap],
        copl: vec![LBL_NONE; cap],
    };
    for id in graph.node_ids() {
        let n = graph.node(id);
        dsu.colo[id.0] = intern(n.colocation_group.as_deref());
        dsu.copl[id.0] = intern(n.coplacement_group.as_deref());
    }

    for _round in 0..cfg.rounds {
        // Quotient edges, deduplicated.
        let mut qedges: Vec<(usize, usize)> = Vec::new();
        for id in graph.node_ids() {
            let ru = dsu.find(id.0);
            for &(v, _) in csr.out(id) {
                let rv = dsu.find(v.0);
                if ru != rv {
                    qedges.push((ru, rv));
                }
            }
        }
        qedges.sort_unstable();
        qedges.dedup();
        let mut outdeg = vec![0u32; cap];
        let mut indeg = vec![0u32; cap];
        for &(ru, rv) in &qedges {
            outdeg[ru] += 1;
            indeg[rv] += 1;
        }

        let mut progressed = false;
        for &(ru, rv) in &qedges {
            if outdeg[ru] != 1 {
                continue;
            }
            let chain_ok = cfg.fuse_chains && indeg[rv] == 1;
            let group_ok =
                cfg.fuse_groups && dsu.copl[ru] >= 0 && dsu.copl[ru] == dsu.copl[rv];
            if !chain_ok && !group_ok {
                continue;
            }
            let a = dsu.find(ru);
            let b = dsu.find(rv);
            if a == b {
                continue; // already merged via another selected edge
            }
            if dsu.size[a] + dsu.size[b] > max_members {
                continue;
            }
            // Never merge two *different* colocation groups: their
            // members are pinned to (potentially) different devices.
            if dsu.colo[a] >= 0 && dsu.colo[b] >= 0 && dsu.colo[a] != dsu.colo[b] {
                continue;
            }
            // Union by size; fold labels into the surviving root.
            let (root, child) = if dsu.size[a] >= dsu.size[b] {
                (a, b)
            } else {
                (b, a)
            };
            dsu.parent[child] = root;
            dsu.size[root] += dsu.size[child];
            if dsu.colo[root] == LBL_NONE {
                dsu.colo[root] = dsu.colo[child];
            }
            if dsu.copl[root] != dsu.copl[child] {
                dsu.copl[root] = LBL_CONFLICT;
            }
            progressed = true;
        }
        if !progressed {
            break;
        }
    }

    // Assign coarse ids in order of smallest member id (deterministic).
    let mut super_of: Vec<Option<NodeId>> = vec![None; cap];
    let mut root_to_coarse: Vec<usize> = vec![usize::MAX; cap];
    let mut members: Vec<Vec<NodeId>> = Vec::new();
    let mut roots: Vec<usize> = Vec::new();
    for id in graph.node_ids() {
        let r = dsu.find(id.0);
        if root_to_coarse[r] == usize::MAX {
            root_to_coarse[r] = members.len();
            members.push(Vec::new());
            roots.push(r);
        }
        let cid = root_to_coarse[r];
        super_of[id.0] = Some(NodeId(cid));
        members[cid].push(id);
    }

    // Build the coarse graph: aggregated nodes first.
    let mut coarse = OpGraph::new(&format!("{} (coarse)", graph.name));
    for (cid, mem_ids) in members.iter().enumerate() {
        let first = graph.node(mem_ids[0]);
        let name = if mem_ids.len() == 1 {
            first.name.clone()
        } else {
            format!("{}+{}", first.name, mem_ids.len() - 1)
        };
        let id = coarse.add_node(&name, first.kind.clone());
        debug_assert_eq!(id.0, cid);
        let node = coarse.node_mut(id);
        let mut mem = MemorySpec::default();
        let mut compute = 0.0f64;
        let mut output_bytes = 0u64;
        let mut all_backward = true;
        for &m in mem_ids {
            let n = graph.node(m);
            compute += n.compute;
            mem.params += n.mem.params;
            mem.output += n.mem.output;
            mem.param_grad += n.mem.param_grad;
            mem.upstream_grad += n.mem.upstream_grad;
            mem.temp += n.mem.temp;
            output_bytes += n.output_bytes;
            all_backward &= n.is_backward;
        }
        node.compute = compute;
        node.mem = mem;
        node.output_bytes = output_bytes;
        node.is_backward = all_backward;
        node.fused_from = mem_ids.clone();
        let root = roots[cid];
        if dsu.colo[root] >= 0 {
            node.colocation_group = graph
                .node(mem_ids[0])
                .colocation_group
                .clone()
                .or_else(|| {
                    mem_ids
                        .iter()
                        .find_map(|&m| graph.node(m).colocation_group.clone())
                });
        }
        if dsu.copl[root] >= 0 {
            // Label only meaningful when *every* member shares it.
            let lbl = graph.node(mem_ids[0]).coplacement_group.clone();
            if lbl.is_some()
                && mem_ids
                    .iter()
                    .all(|&m| graph.node(m).coplacement_group == lbl)
            {
                node.coplacement_group = lbl;
            }
        }
    }

    // Cut edges: per-source max into each destination super, summed over
    // distinct sources. Collected flat and sorted so each coarse edge is
    // added exactly once (OpGraph::add_edge would max-merge duplicates).
    let mut cut: Vec<(usize, usize, usize, u64)> = Vec::new(); // (cu, cv, u, bytes)
    for id in graph.node_ids() {
        let cu = super_of[id.0].unwrap().0;
        for &(v, bytes) in csr.out(id) {
            let cv = super_of[v.0].unwrap().0;
            if cu != cv {
                cut.push((cu, cv, id.0, bytes));
            }
        }
    }
    cut.sort_unstable();
    let mut i = 0;
    while i < cut.len() {
        let (cu, cv, _, _) = cut[i];
        let mut total = 0u64;
        while i < cut.len() && cut[i].0 == cu && cut[i].1 == cv {
            let u = cut[i].2;
            let mut max_bytes = 0u64;
            while i < cut.len() && cut[i].0 == cu && cut[i].1 == cv && cut[i].2 == u {
                max_bytes = max_bytes.max(cut[i].3);
                i += 1;
            }
            total += max_bytes;
        }
        coarse.add_edge(NodeId(cu), NodeId(cv), total);
    }

    debug_assert!(coarse.is_acyclic(), "contraction created a cycle");
    Coarse {
        graph: coarse,
        super_of,
        members,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 10,
                output: 5,
                param_grad: 3,
                upstream_grad: 2,
                temp: 1,
            };
            g.node_mut(id).output_bytes = 5;
            if let Some(p) = prev {
                g.add_edge(p, id, 5);
            }
            prev = Some(id);
        }
        g
    }

    #[test]
    fn chain_contracts_to_one_super() {
        let g = chain(6);
        let c = coarsen(&g, &CoarsenConfig::default());
        assert_eq!(c.graph.len(), 1);
        let s = c.graph.node(NodeId(0));
        assert!((s.compute - 6.0).abs() < 1e-12);
        assert_eq!(s.mem.params, 60);
        assert_eq!(s.mem.output, 30);
        assert_eq!(s.mem.param_grad, 18);
        assert_eq!(s.mem.upstream_grad, 12);
        assert_eq!(s.mem.temp, 6);
        assert_eq!(c.members[0].len(), 6);
        assert_eq!(s.fused_from.len(), 6);
    }

    #[test]
    fn max_members_caps_super_size() {
        let g = chain(10);
        let cfg = CoarsenConfig {
            max_members: 3,
            ..CoarsenConfig::default()
        };
        let c = coarsen(&g, &cfg);
        assert!(c.graph.len() >= 4, "10 ops / ≤3 members ⇒ ≥4 supers");
        for m in &c.members {
            assert!(m.len() <= 3);
        }
        assert!(c.graph.is_acyclic());
    }

    #[test]
    fn diamond_keeps_parallel_branches() {
        // a → (b, c) → d: no quotient edge satisfies the chain rule, so
        // the parallelism survives coarsening.
        let mut g = OpGraph::new("diamond");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        g.add_edge(b, d, 1);
        g.add_edge(c, d, 1);
        let coarse = coarsen(&g, &CoarsenConfig::default());
        assert_eq!(coarse.graph.len(), 4);
    }

    #[test]
    fn coplacement_group_fuses_fan_in() {
        // b and c both feed d; all three share a co-placement group, so
        // the group rule may contract b→d and c→d despite d's fan-in.
        let mut g = OpGraph::new("grp");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::Loss);
        for id in [b, c, d] {
            g.node_mut(id).coplacement_group = Some("g0".into());
        }
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        g.add_edge(b, d, 1);
        g.add_edge(c, d, 1);
        let coarse = coarsen(&g, &CoarsenConfig::default());
        // a stays; {b, c, d} collapse (possibly over two rounds).
        assert_eq!(coarse.graph.len(), 2);
        assert!(coarse.graph.is_acyclic());
        let sup = coarse.super_of[d.0].unwrap();
        assert_eq!(coarse.super_of[b.0], Some(sup));
        assert_eq!(coarse.super_of[c.0], Some(sup));
    }

    #[test]
    fn cut_edge_bytes_take_per_source_max() {
        // a feeds two members of the same destination super: the tensor
        // is transferred once per destination device (§4.2), so the
        // coarse edge carries max(20, 30), not the sum.
        let mut g = OpGraph::new("cut");
        let a = g.add_node("a", OpKind::Input);
        let b1 = g.add_node("b1", OpKind::MatMul);
        let b2 = g.add_node("b2", OpKind::MatMul);
        for id in [b1, b2] {
            g.node_mut(id).coplacement_group = Some("dst".into());
        }
        g.add_edge(a, b1, 20);
        g.add_edge(a, b2, 30);
        g.add_edge(b1, b2, 1); // group rule merges {b1, b2}
        let coarse = coarsen(&g, &CoarsenConfig::default());
        assert_eq!(coarse.graph.len(), 2);
        let ca = coarse.super_of[a.0].unwrap();
        let cb = coarse.super_of[b1.0].unwrap();
        assert_eq!(coarse.graph.edge_bytes(ca, cb), Some(30));
    }

    #[test]
    fn distinct_colocation_groups_never_merge() {
        let mut g = chain(2);
        g.node_mut(NodeId(0)).colocation_group = Some("g0".into());
        g.node_mut(NodeId(1)).colocation_group = Some("g1".into());
        let c = coarsen(&g, &CoarsenConfig::default());
        assert_eq!(c.graph.len(), 2, "colocation conflict blocks the merge");
    }

    #[test]
    fn zero_rounds_is_identity_on_node_sets() {
        let g = chain(5);
        let cfg = CoarsenConfig {
            rounds: 0,
            ..CoarsenConfig::default()
        };
        let c = coarsen(&g, &cfg);
        assert_eq!(c.graph.len(), 5);
        for (cid, m) in c.members.iter().enumerate() {
            assert_eq!(m.len(), 1);
            assert_eq!(c.super_of[m[0].0], Some(NodeId(cid)));
        }
    }
}
