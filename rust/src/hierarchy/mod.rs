//! Hierarchical placement for million-op graphs: coarsen → place → refine.
//!
//! Baechi's headline result is placement *speed* — seconds where
//! learning-based placers need hours — but flat m-SCT still walks every
//! op through a priority queue with per-device entries. For 100K–1M-op
//! graphs this module first **coarsens** the graph
//! ([`coarsen::coarsen`]): linear chains and optimizer co-placement
//! groups contract into super-ops with summed compute/memory and
//! aggregated cut-edge bytes (cycle-safe by construction — see the
//! module docs). The far smaller coarse graph is placed with the
//! existing m-SCT, and a **refine** pass ([`refine::refine`]) expands
//! every super-op back onto the original ops: boundary ops stay pinned
//! to their super's device, interior ops greedily min-EST within the
//! memory budget, colocation constraints dominate throughout.
//!
//! Tarnawski et al. (PAPERS.md) is the algorithmic reference for
//! partitioning quality; this pass optimizes for *speed* first — the
//! quality contract is that the coarse placement's cut structure
//! survives refinement and memory capacity is never violated.
//!
//! **Correctness contract** (property-tested in `prop_invariants`):
//! with coarsening disabled ([`CoarsenConfig::off`]) the [`HierPlacer`]
//! delegates wholesale to [`MSct`] and is bit-identical to it; with
//! coarsening enabled, refined placements always respect per-device
//! memory. If the coarse graph's (conservatively summed) super-ops
//! cannot be placed under tight memory, the placer falls back to flat
//! m-SCT rather than failing where m-SCT would succeed.

pub mod coarsen;
pub mod refine;

pub use coarsen::{coarsen, Coarse, CoarsenConfig};

use crate::error::BaechiError;
use crate::graph::OpGraph;
use crate::placer::{msct::MSct, Placement, Placer};
use crate::profile::Cluster;

/// The hierarchical placer: coarsen → m-SCT on the coarse graph →
/// refine. Registered in the engine registry as `hier` (args:
/// `hier:off` disables coarsening, `hier:<n>` caps super-op size).
#[derive(Debug, Clone, Copy, Default)]
pub struct HierPlacer {
    pub cfg: CoarsenConfig,
}

impl HierPlacer {
    pub fn new(cfg: CoarsenConfig) -> HierPlacer {
        HierPlacer { cfg }
    }
}

impl Placer for HierPlacer {
    fn name(&self) -> String {
        if self.cfg.enabled {
            "hier".to_string()
        } else {
            "hier(off)".to_string()
        }
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        if !self.cfg.enabled {
            // Bit-identity contract: no coarsening means *exactly* plain
            // m-SCT — same favorites, same schedule, same result.
            return MSct::default().place(graph, cluster);
        }
        let t0 = std::time::Instant::now();
        if !graph.is_acyclic() {
            return Err(BaechiError::Cyclic);
        }
        let coarse = coarsen(graph, &self.cfg);
        let coarse_placement = match MSct::default().place(&coarse.graph, cluster) {
            Ok(p) => p,
            // Super-op memory is the conservative sum of members, so a
            // tightly packed cluster can OOM at coarse granularity where
            // op granularity would fit. Fall back to flat m-SCT instead
            // of failing a placeable graph.
            Err(BaechiError::Oom { .. }) => {
                if crate::explain::is_live() {
                    crate::explain::decision::note(
                        "hier: coarse placement OOM (conservative super-op sums); \
                         falling back to flat m-SCT",
                    );
                }
                return MSct::default().place(graph, cluster);
            }
            Err(e) => return Err(e),
        };
        let refined = match refine::refine(graph, &coarse, &coarse_placement, cluster) {
            Ok(r) => r,
            Err(BaechiError::Oom { .. }) => {
                if crate::explain::is_live() {
                    crate::explain::decision::note(
                        "hier: refine ran out of memory expanding super-ops; \
                         falling back to flat m-SCT",
                    );
                }
                return MSct::default().place(graph, cluster);
            }
            Err(e) => return Err(e),
        };
        let (device_of, predicted_makespan, peak_memory) = refined;
        Ok(Placement {
            algorithm: "hier".to_string(),
            device_of,
            predicted_makespan,
            placement_time: t0.elapsed().as_secs_f64(),
            peak_memory,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, NodeId, OpKind};
    use crate::profile::CommModel;

    fn unit_cluster(n: usize, mem: u64) -> Cluster {
        Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
    }

    fn layered(nodes: usize) -> OpGraph {
        crate::models::synthetic::synthetic_graph(nodes)
    }

    #[test]
    fn hier_disabled_is_plain_msct() {
        let g = layered(200);
        let cluster = unit_cluster(4, 1 << 30);
        let flat = MSct::default().place(&g, &cluster).unwrap();
        let hier = HierPlacer::new(CoarsenConfig::off())
            .place(&g, &cluster)
            .unwrap();
        assert_eq!(hier.algorithm, flat.algorithm);
        assert_eq!(hier.device_of, flat.device_of);
        assert_eq!(hier.predicted_makespan, flat.predicted_makespan);
        assert_eq!(hier.peak_memory, flat.peak_memory);
    }

    #[test]
    fn hier_places_every_op_within_memory() {
        let g = layered(500);
        let cluster = unit_cluster(4, 1 << 30);
        let p = HierPlacer::default().place(&g, &cluster).unwrap();
        assert_eq!(p.algorithm, "hier");
        assert_eq!(p.device_of.len(), g.len());
        for (d, &peak) in p.peak_memory.iter().enumerate() {
            assert!(peak <= 1 << 30, "device {d} peak {peak}");
        }
    }

    #[test]
    fn hier_falls_back_to_flat_msct_under_tight_memory() {
        let mut g = OpGraph::new("tight");
        let mut prev: Option<NodeId> = None;
        for i in 0..4 {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 3,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 1);
            }
            prev = Some(id);
        }
        // 2 devices × 7 bytes: the whole chain contracts to one 12-byte
        // super-op that fits nowhere, but flat m-SCT places two 3-byte
        // ops per device — the coarse-OOM fallback must kick in.
        let p = HierPlacer::default().place(&g, &unit_cluster(2, 7)).unwrap();
        assert_eq!(p.device_of.len(), 4);
        for &peak in &p.peak_memory {
            assert!(peak <= 7);
        }
    }
}
