//! Refine pass: expand super-ops and re-place their members.
//!
//! After the coarse graph is placed, every original op inherits its
//! super-op's device as a *starting point*. The refine sweep walks the
//! original graph in depth-bucket order
//! ([`crate::placer::sched::ReadyBuckets`]) under a full
//! [`MemoryLedger`], exactly like the incremental serving path
//! (`serve/incremental.rs`):
//!
//! * **colocation-pinned** ops follow their group's ledger pin (dominates
//!   everything — TF semantics);
//! * **boundary** ops (any edge crossing supers) stay pinned to their
//!   super's device so the coarse placement's cut decisions survive,
//!   falling back to greedy min-EST only if memory no longer allows it;
//! * **interior** ops min-EST across all devices, preferring the super's
//!   device on ties — cheap local slack recovery without disturbing the
//!   coarse structure.
//!
//! Memory is checked (`ledger.fits`) before every commit, so refined
//! placements respect per-device capacity *by construction*
//! (property-tested in `prop_invariants`).

use super::coarsen::Coarse;
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::placer::ledger::MemoryLedger;
use crate::placer::sched::ReadyBuckets;
use crate::placer::{oom_error, Placement};
use crate::profile::Cluster;
use std::collections::BTreeMap;

/// Expand `coarse_placement` onto the original graph. Returns
/// `(device_of, predicted_makespan, peak_memory)`.
pub fn refine(
    graph: &OpGraph,
    coarse: &Coarse,
    coarse_placement: &Placement,
    cluster: &Cluster,
) -> crate::Result<(BTreeMap<NodeId, DeviceId>, f64, Vec<u64>)> {
    let cap = graph.capacity();
    let n_dev = cluster.n();
    let topo = cluster.effective_topology();
    let caps: Vec<u64> = cluster.devices.iter().map(|d| d.memory).collect();

    // Each original op's super device, and whether it sits on a cut.
    let mut super_dev: Vec<Option<DeviceId>> = vec![None; cap];
    let mut boundary = vec![false; cap];
    for id in graph.node_ids() {
        let sup = coarse.super_of[id.0].expect("live node has a super");
        super_dev[id.0] = Some(coarse_placement.device(sup));
        for &(v, _) in graph.successors(id) {
            if coarse.super_of[v.0] != Some(sup) {
                boundary[id.0] = true;
                boundary[v.0] = true;
            }
        }
    }

    let depths = graph.depths();
    let max_depth = depths.iter().copied().max().unwrap_or(0);
    let mut ready = ReadyBuckets::new(max_depth);
    let mut preds_left = vec![0usize; cap];
    for id in graph.node_ids() {
        preds_left[id.0] = graph.in_degree(id);
        if preds_left[id.0] == 0 {
            ready.push(id, depths[id.0]);
        }
    }

    let mut ledger = MemoryLedger::new(graph, &caps);
    let mut dev_ready = vec![0.0f64; n_dev];
    let mut finish = vec![0.0f64; cap];
    let mut device_of: BTreeMap<NodeId, DeviceId> = BTreeMap::new();
    let mut makespan = 0.0f64;

    let est = |id: NodeId, d: DeviceId, dev_ready: &[f64], finish: &[f64], homes: &[Option<DeviceId>]| {
        let mut t = dev_ready[d.0];
        for &(p, bytes) in graph.predecessors(id) {
            let pd = homes[p.0].expect("pred scheduled before successor");
            let arrive = finish[p.0]
                + if pd == d {
                    0.0
                } else {
                    topo.pair(pd.0, d.0).time(bytes)
                };
            if arrive > t {
                t = arrive;
            }
        }
        t
    };
    // Dense mirror of device_of for O(1) predecessor lookups in `est`.
    let mut homes: Vec<Option<DeviceId>> = vec![None; cap];

    while let Some(id) = ready.pop() {
        let node = graph.node(id);
        let home = super_dev[id.0].expect("live node");
        let mut reason = crate::explain::DecisionReason::MinEst;
        let choice = if let Some(pin) = ledger.pinned_device(graph, id) {
            // Colocation dominates: the group is already reserved there.
            if !ledger.fits(graph, id, pin) {
                return Err(oom_error(graph, id, &ledger));
            }
            reason = crate::explain::DecisionReason::CoarsenPin;
            pin
        } else if boundary[id.0] && ledger.fits(graph, id, home) {
            reason = crate::explain::DecisionReason::CoarsenPin;
            home
        } else {
            if !ledger.fits(graph, id, home) {
                // The coarse placement wanted `home`; memory no longer
                // allows it and the greedy sweep must divert.
                reason = crate::explain::DecisionReason::OomFallback;
            }
            // Interior op (or a boundary op whose super device is out of
            // memory): greedy min-EST. The super's device is probed
            // first, so strict `<` comparison prefers it on ties, then
            // lowest device id.
            let mut best: Option<(f64, DeviceId)> = None;
            for d in std::iter::once(home)
                .chain((0..n_dev).map(DeviceId).filter(|&d| d != home))
            {
                if !ledger.fits(graph, id, d) {
                    continue;
                }
                let t = est(id, d, &dev_ready, &finish, &homes);
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, d));
                }
            }
            match best {
                Some((_, d)) => d,
                None => return Err(oom_error(graph, id, &ledger)),
            }
        };
        if crate::explain::is_live() {
            let candidates = (0..n_dev)
                .map(|d| {
                    let dev = DeviceId(d);
                    let mut data_ready = 0.0f64;
                    for &(p, bytes) in graph.predecessors(id) {
                        let pd = homes[p.0].expect("pred scheduled before successor");
                        let arrive = finish[p.0]
                            + if pd == dev {
                                0.0
                            } else {
                                topo.pair(pd.0, d).time(bytes)
                            };
                        data_ready = data_ready.max(arrive);
                    }
                    let (cand_est, deficit) = match ledger.required_on(graph, id, dev) {
                        None => (None, 0),
                        Some(need) => {
                            let free = ledger.devices[d].free();
                            if need <= free {
                                (Some(data_ready.max(dev_ready[d])), 0)
                            } else {
                                (None, need - free)
                            }
                        }
                    };
                    crate::explain::Candidate {
                        device: d,
                        est: cand_est,
                        data_ready,
                        device_free: dev_ready[d],
                        memory_deficit: deficit,
                    }
                })
                .collect();
            crate::explain::decision::record(crate::explain::Decision {
                node: id,
                name: node.name.clone(),
                chosen: choice.0,
                reason,
                candidates,
            });
        }
        ledger.commit(graph, id, choice);
        let start = est(id, choice, &dev_ready, &finish, &homes);
        let done = start + node.compute / cluster.devices[choice.0].speed.max(1e-12);
        finish[id.0] = done;
        dev_ready[choice.0] = done;
        makespan = makespan.max(done);
        homes[id.0] = Some(choice);
        device_of.insert(id, choice);
        for &(s, _) in graph.successors(id) {
            preds_left[s.0] -= 1;
            if preds_left[s.0] == 0 {
                ready.push(s, depths[s.0]);
            }
        }
    }

    debug_assert_eq!(device_of.len(), graph.len(), "refine covered every op");
    Ok((device_of, makespan, ledger.peaks()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpKind};
    use crate::hierarchy::coarsen::{coarsen, CoarsenConfig};
    use crate::placer::{msct::MSct, Placer};
    use crate::profile::CommModel;

    fn unit_cluster(n: usize, mem: u64) -> Cluster {
        Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
    }

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 10,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 2);
            }
            prev = Some(id);
        }
        g
    }

    #[test]
    fn refine_covers_every_op_and_respects_memory() {
        let g = chain(8);
        let cluster = unit_cluster(2, 1000);
        let coarse = coarsen(&g, &CoarsenConfig::with_max_members(3));
        let cp = MSct::default().place(&coarse.graph, &cluster).unwrap();
        let (device_of, makespan, peaks) = refine(&g, &coarse, &cp, &cluster).unwrap();
        assert_eq!(device_of.len(), 8);
        assert!(makespan >= 8.0 - 1e-9, "8 × 1 s of serial work");
        for (d, &p) in peaks.iter().enumerate() {
            assert!(p <= 1000, "device {d} peak {p}");
        }
    }

    #[test]
    fn refine_keeps_colocation_groups_together() {
        let mut g = chain(6);
        g.node_mut(NodeId(0)).colocation_group = Some("w".into());
        g.node_mut(NodeId(5)).colocation_group = Some("w".into());
        let cluster = unit_cluster(2, 1000);
        let coarse = coarsen(&g, &CoarsenConfig::with_max_members(2));
        let cp = MSct::default().place(&coarse.graph, &cluster).unwrap();
        let (device_of, _, _) = refine(&g, &coarse, &cp, &cluster).unwrap();
        assert_eq!(device_of[&NodeId(0)], device_of[&NodeId(5)]);
    }
}
