//! Baechi CLI — the Layer-3 leader entrypoint.
//!
//! ```text
//! baechi place   --model gnmt:128:40 --placer m-sct [--memory-fraction 0.3]
//! baechi place   --model gnmt:32:10 --topology two-tier:2 --replace-rounds 3
//! baechi place   --model gnmt:32:10 --calibrate synthetic:0.02
//! baechi compare --model transformer:64
//! baechi calibrate --source synthetic --topology two-tier:2 --out calib.json
//! baechi e2e     --steps 200 --devices 2 [--placer m-sct]
//! baechi serve-bench --model gnmt:16:8 --requests 500 --mutation-rate 0.3
//! baechi serve-bench --trace serve.json --metrics-addr 127.0.0.1:9184
//! baechi trace   --model linreg --placer m-etf --out trace.json
//! baechi explain --model inception --placer m-sct [--top 5]
//! baechi explain --model gnmt:32:10 --placer m-sct --op lstm_3_fwd
//! baechi explain --model transformer:64 --placer m-etf --diff-placer m-sct
//! baechi info    --model inception:32
//! ```
//!
//! Every command routes through the [`baechi::engine::PlacementEngine`]:
//! `place` issues one request, `compare` serves a batch across placers
//! (fanned over threads, with typed per-row error handling). `trace`
//! (and `--trace` on `place`/`serve-bench`) exports a Chrome
//! trace-event timeline of the run — pipeline spans plus the simulated
//! per-device/per-link schedule — loadable in `chrome://tracing` or
//! Perfetto.

use baechi::coordinator::{
    engine_for, run, run_explained, run_serve_bench, run_traced, BaechiConfig, CalibrationSpec,
    PlacerKind, ServeBenchOpts, TopologySpec,
};
use baechi::engine::PlacementRequest;
use baechi::models::Benchmark;
use baechi::util::cli::{Args, OptSpec};
use baechi::util::json::Json;
use baechi::util::table::{fmt_bytes, fmt_secs, Table};
use baechi::BaechiError;

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec {
            name: "model",
            help: "benchmark: inception[:bs] | gnmt[:bs[:len]] | transformer[:bs] | linreg | mlp | synthetic[:ops]",
            takes_value: true,
            default: Some("transformer:64"),
        },
        OptSpec {
            name: "placer",
            help: "single | expert | m-topo | m-etf | m-sct | m-sct-heur | m-sct-lp | rl[:episodes] | hier[:off|:members]",
            takes_value: true,
            default: Some("m-sct"),
        },
        OptSpec {
            name: "devices",
            help: "number of devices",
            takes_value: true,
            default: Some("4"),
        },
        OptSpec {
            name: "memory-gb",
            help: "memory per device in GiB",
            takes_value: true,
            default: Some("8"),
        },
        OptSpec {
            name: "memory-fraction",
            help: "fraction of device memory available (Table 5)",
            takes_value: true,
            default: Some("1.0"),
        },
        OptSpec {
            name: "steps",
            help: "e2e: training steps",
            takes_value: true,
            default: Some("200"),
        },
        OptSpec {
            name: "lr",
            help: "e2e: learning rate",
            takes_value: true,
            default: Some("0.05"),
        },
        OptSpec {
            name: "topology",
            help: "cluster interconnect: uniform | nvlink-islands:<island>[:<ratio>] | \
                   two-tier:<nodes>[:<ratio>] | <path>.json",
            takes_value: true,
            default: Some("uniform"),
        },
        OptSpec {
            name: "calibrate",
            help: "cluster-model calibration: off | synthetic[:<noise>] | runtime | \
                   <artifact>.json (replaces the hand-specified topology with a measured one)",
            takes_value: true,
            default: Some("off"),
        },
        OptSpec {
            name: "source",
            help: "calibrate: measurement source (synthetic[:<noise>] | runtime)",
            takes_value: true,
            default: Some("synthetic"),
        },
        OptSpec {
            name: "out",
            help: "calibrate: write the CalibratedCluster artifact to this path",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "replace-rounds",
            help: "contention-driven re-placement rounds (0 = single-shot placement)",
            takes_value: true,
            default: Some("0"),
        },
        OptSpec {
            name: "replace-threshold",
            help: "link-utilization fraction that triggers re-placement",
            takes_value: true,
            default: Some("0.5"),
        },
        OptSpec {
            name: "dot",
            help: "place: write the placed graph as Graphviz DOT (islands grouped, \
                   cross-island edges highlighted)",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "requests",
            help: "serve-bench: total requests in the stream",
            takes_value: true,
            default: Some("200"),
        },
        OptSpec {
            name: "clients",
            help: "serve-bench: closed-loop client threads",
            takes_value: true,
            default: Some("4"),
        },
        OptSpec {
            name: "mutation-rate",
            help: "serve-bench: probability each request mutates the graph",
            takes_value: true,
            default: Some("0.3"),
        },
        OptSpec {
            name: "cache-shards",
            help: "serve-bench: engine placement-cache shard count",
            takes_value: true,
            default: Some("8"),
        },
        OptSpec {
            name: "serve-workers",
            help: "serve-bench: service worker threads",
            takes_value: true,
            default: Some("2"),
        },
        OptSpec {
            name: "no-incremental",
            help: "serve-bench: disable the incremental (delta) placement path",
            takes_value: false,
            default: None,
        },
        OptSpec {
            name: "trace",
            help: "place/serve-bench: write a Chrome trace-event JSON timeline to this path",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "op",
            help: "explain: show the decision record for one op (name or node id)",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "top",
            help: "explain: how many critical-path ops to list",
            takes_value: true,
            default: Some("10"),
        },
        OptSpec {
            name: "diff-placer",
            help: "explain: second placer to diff per-op device choices against",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "metrics-addr",
            help: "serve-bench: serve Prometheus metrics over HTTP at this address \
                   (e.g. 127.0.0.1:9184) for the duration of the run",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "json",
            help: "emit the report as JSON",
            takes_value: false,
            default: None,
        },
        OptSpec {
            name: "no-opt",
            help: "disable the graph optimizer (Table 6 ablation)",
            takes_value: false,
            default: None,
        },
    ]
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> baechi::Result<()> {
    let args = Args::parse(&specs())?;
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("compare");
    match cmd {
        "place" => cmd_place(&args),
        "compare" => cmd_compare(&args),
        "calibrate" => cmd_calibrate(&args),
        "e2e" => cmd_e2e(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "trace" => cmd_trace(&args),
        "explain" => cmd_explain(&args),
        "info" => cmd_info(&args),
        other => Err(BaechiError::invalid(format!(
            "unknown command '{other}' \
             (place|compare|calibrate|e2e|serve-bench|trace|explain|info)\n{}",
            args.usage()
        ))),
    }
}

fn config_from(args: &Args) -> baechi::Result<BaechiConfig> {
    let benchmark = Benchmark::parse(&args.get_or("model", "transformer:64"))?;
    let placer = PlacerKind::parse(&args.get_or("placer", "m-sct"))?;
    let mut cfg = BaechiConfig::paper_default(benchmark, placer);
    cfg.devices = args.get_usize("devices", 4)?;
    cfg.device_memory = (args.get_f64("memory-gb", 8.0)? * (1u64 << 30) as f64) as u64;
    cfg.memory_fraction = args.get_f64("memory-fraction", 1.0)?;
    cfg.topology = TopologySpec::parse(&args.get_or("topology", "uniform"))?;
    cfg.calibrate = CalibrationSpec::parse(&args.get_or("calibrate", "off"))?;
    cfg.replace_rounds = args.get_usize("replace-rounds", 0)?;
    cfg.replace_threshold = args.get_f64("replace-threshold", 0.5)?;
    if args.has("no-opt") {
        cfg.opt = baechi::optimizer::OptConfig::none();
    }
    Ok(cfg)
}

fn write_trace(path: &str, trace: &Json) -> baechi::Result<()> {
    std::fs::write(path, trace.pretty())
        .map_err(|e| BaechiError::io(format!("writing {path}: {e}")))?;
    let events = match trace.get("traceEvents") {
        Some(Json::Arr(a)) => a.len(),
        _ => 0,
    };
    eprintln!("wrote {path} ({events} trace events; load in chrome://tracing or Perfetto)");
    Ok(())
}

fn cmd_place(args: &Args) -> baechi::Result<()> {
    let cfg = config_from(args)?;
    let report = match args.get("trace") {
        Some(path) => {
            let (report, trace) = run_traced(&cfg)?;
            write_trace(&path, &trace)?;
            report
        }
        None => run(&cfg)?,
    };
    if let Some(path) = args.get("dot") {
        // Only an explicit --dot pays for rebuilding the cluster (the
        // topology's link paths) and the benchmark graph.
        let cluster = cfg.cluster()?;
        let graph = cfg.benchmark.graph();
        let dot = graph.to_dot_topology(&report.device_of, cluster.effective_topology().as_ref());
        std::fs::write(&path, dot)
            .map_err(|e| BaechiError::io(format!("writing {path}: {e}")))?;
        eprintln!("wrote {path}");
    }
    if args.has("json") {
        println!("{}", report.to_json().pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("placement: {} via {}", report.benchmark, report.placer),
        &["metric", "value"],
    );
    t.row_strs(&["topology", &report.topology]);
    t.row_strs(&["ops (original)", &report.original_ops.to_string()]);
    t.row_strs(&["ops (placed)", &report.placed_ops.to_string()]);
    t.row_strs(&["placement time", &fmt_secs(report.placement_time)]);
    t.row_strs(&["predicted makespan", &fmt_secs(report.predicted_makespan)]);
    match report.step_time() {
        Some(s) => t.row_strs(&["simulated step time", &fmt_secs(s)]),
        None => t.row_strs(&["simulated step time", "OOM"]),
    };
    t.row_strs(&["devices used", &report.devices_used.to_string()]);
    if let Some(cal) = &report.calibration {
        t.row_strs(&[
            "calibration",
            &format!(
                "{} → mean pair error {:.2}%, {} warning(s)",
                cal.source,
                cal.mean_rel_error * 100.0,
                cal.warnings.len()
            ),
        ]);
    }
    if let Some(rep) = &report.replacement {
        for rd in &rep.rounds {
            let tag = if rd.improved { ", improved" } else { "" };
            let step = if rd.oom {
                "OOM".to_string()
            } else {
                fmt_secs(rd.makespan)
            };
            t.row_strs(&[
                &format!("replace round {}", rd.round),
                &format!(
                    "{step} ({} saturated links, {:.0}% peak link util{tag})",
                    rd.saturated_links.len(),
                    rd.max_utilization * 100.0
                ),
            ]);
        }
        let gain = baechi::feedback::relative_gain(rep.baseline_makespan, report.sim.makespan);
        t.row_strs(&["replacement gain", &format!("{:+.1}%", gain * 100.0)]);
    }
    for (i, &p) in report.peak_memory.iter().enumerate() {
        t.row_strs(&[&format!("peak memory gpu{i}"), &fmt_bytes(p)]);
    }
    if let Some(oom) = &report.sim.oom {
        t.row_strs(&["OOM detail", &oom.to_string()]);
    }
    t.print();
    Ok(())
}

fn cmd_compare(args: &Args) -> baechi::Result<()> {
    let base = config_from(args)?;
    // One engine, one batch request per placer — the serving path.
    let engine = engine_for(&base)?;
    let kinds = [
        PlacerKind::Single,
        PlacerKind::Expert,
        PlacerKind::MTopo,
        PlacerKind::MEtf,
        PlacerKind::MSct,
    ];
    let reqs: Vec<PlacementRequest> = kinds
        .iter()
        .map(|k| PlacementRequest::for_benchmark(base.benchmark, &k.spec()))
        .collect();
    let results = engine.place_batch(&reqs);

    let mut t = Table::new(
        &format!(
            "compare: {} on {} devices ({} each, fraction {})",
            base.benchmark.name(),
            base.devices,
            fmt_bytes(base.device_memory),
            base.memory_fraction
        ),
        &["placer", "placement time", "step time", "devices used"],
    );
    for (kind, result) in kinds.iter().zip(results) {
        match result {
            Ok(r) => {
                let step = r
                    .sim
                    .as_ref()
                    .filter(|s| s.ok())
                    .map(|s| fmt_secs(s.makespan))
                    .unwrap_or_else(|| "OOM".into());
                t.row(&[
                    r.placer.clone(),
                    fmt_secs(r.placement.placement_time),
                    step,
                    r.devices_used.to_string(),
                ]);
            }
            Err(BaechiError::Oom {
                op,
                best_device,
                deficit,
            }) => {
                let detail = match best_device {
                    Some(d) => format!("OOM at {op} ({d} short {})", fmt_bytes(deficit)),
                    None => format!("OOM at {op}"),
                };
                t.row(&[kind.name().to_string(), "-".into(), detail, "-".into()]);
            }
            Err(e) => {
                t.row(&[
                    kind.name().to_string(),
                    "-".into(),
                    format!("placement failed: {e}"),
                    "-".into(),
                ]);
            }
        }
    }
    t.print();
    Ok(())
}

fn cmd_calibrate(args: &Args) -> baechi::Result<()> {
    let cfg = config_from(args)?;
    let spec = CalibrationSpec::parse(&args.get_or("source", "synthetic"))?;
    if spec == CalibrationSpec::Off {
        return Err(BaechiError::invalid(
            "calibrate: source 'off' measures nothing \
             (synthetic[:<noise>] | runtime | <artifact>.json)",
        ));
    }
    // The hand-specified topology doubles as the synthetic ground truth.
    let cal = spec
        .run(cfg.devices, || cfg.truth_topology())?
        .expect("non-off calibration always produces an artifact");
    if let Some(path) = args.get("out") {
        cal.save(&path)?;
        eprintln!("wrote {path}");
    }
    if args.has("json") {
        println!("{}", cal.to_json().pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("calibration: {}", cal.report.source),
        &["metric", "value"],
    );
    t.row_strs(&["devices", &cal.report.devices.to_string()]);
    t.row_strs(&["recovered topology", &cal.topology.describe()]);
    t.row_strs(&["islands", &cal.report.n_islands.to_string()]);
    t.row_strs(&[
        "mean pair error",
        &format!("{:.3}%", cal.report.mean_rel_error * 100.0),
    ]);
    t.row_strs(&[
        "max pair error",
        &format!("{:.3}%", cal.report.max_rel_error * 100.0),
    ]);
    for (d, s) in (0..cal.report.devices)
        .map(|d| (d, cal.topology.speed(d)))
        .filter(|(_, s)| (*s - 1.0).abs() > 1e-9)
    {
        t.row_strs(&[&format!("speed gpu{d}"), &format!("{s:.3}×")]);
    }
    if cal.report.warnings.is_empty() {
        t.row_strs(&["warnings", "none"]);
    } else {
        for (i, w) in cal.report.warnings.iter().enumerate() {
            t.row_strs(&[&format!("warning {i}"), w]);
        }
    }
    t.print();
    Ok(())
}

fn cmd_e2e(args: &Args) -> baechi::Result<()> {
    use baechi::exec::plan::MlpPlan;
    use baechi::exec::trainer::{train_distributed, train_oracle, ModelMeta, TrainConfig};

    let devices = args.get_usize("devices", 2)?;
    let steps = args.get_usize("steps", 200)?;
    let lr = args.get_f64("lr", 0.05)? as f32;
    let placer = PlacerKind::parse(&args.get_or("placer", "m-sct"))?;

    // Place the MLP module graph on memory-tight devices so the placer
    // must genuinely split it.
    let benchmark = Benchmark::Mlp;
    let graph = benchmark.graph();
    let cluster = baechi::profile::Cluster::homogeneous(
        devices,
        320 << 10, // tight: the model cannot fit one device
        baechi::profile::CommModel::pcie_via_host(),
    );
    let engine = baechi::engine::PlacementEngine::builder()
        .cluster(cluster)
        .build()?;
    let resp = engine.place(
        &PlacementRequest::for_benchmark(benchmark, &placer.spec()).without_simulation(),
    )?;
    let meta = ModelMeta::load(&baechi::runtime::artifact::ArtifactRegistry::default_dir())?;
    let plan = MlpPlan::from_placement(&graph, &resp.placement, devices, meta.n_layers())?;
    println!(
        "placement ({}): layers → {:?}, loss → gpu{}",
        resp.placer, plan.layer_dev, plan.loss_dev
    );

    let cfg = TrainConfig {
        steps,
        lr,
        ..Default::default()
    };
    let report = train_distributed(&plan, &cfg)?;
    println!(
        "distributed: {} steps in {:.2}s ({:.1} steps/s) across {} devices",
        steps, report.wall_time, report.steps_per_sec, devices
    );
    for (s, l) in report.losses.iter().enumerate() {
        if s % (steps / 10).max(1) == 0 || s == steps - 1 {
            println!("  step {s:>5}  loss {l:.4}");
        }
    }
    // Oracle check on a prefix.
    let oracle_cfg = TrainConfig {
        steps: steps.min(10),
        lr,
        ..Default::default()
    };
    let oracle = train_oracle(&oracle_cfg)?;
    for (s, (a, b)) in report.losses.iter().zip(&oracle).enumerate() {
        if (a - b).abs() >= 1e-3 * (1.0 + b.abs()) {
            return Err(BaechiError::runtime(format!(
                "divergence at step {s}: {a} vs oracle {b}"
            )));
        }
    }
    println!(
        "oracle check: first {} steps match the fused train_step",
        oracle.len()
    );
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> baechi::Result<()> {
    let cfg = config_from(args)?;
    let opts = ServeBenchOpts {
        requests: args.get_usize("requests", 200)?,
        clients: args.get_usize("clients", 4)?,
        mutation_rate: args.get_f64("mutation-rate", 0.3)?,
        cache_shards: args.get_usize("cache-shards", 8)?,
        workers: args.get_usize("serve-workers", 2)?,
        incremental: !args.has("no-incremental"),
        trace: args.get("trace").is_some(),
        metrics_addr: args.get("metrics-addr"),
        ..ServeBenchOpts::default()
    };
    let report = run_serve_bench(&cfg, &opts)?;
    if let (Some(path), Some(trace)) = (args.get("trace"), &report.trace) {
        write_trace(&path, trace)?;
    }
    if args.has("json") {
        println!("{}", report.to_json().pretty());
        return Ok(());
    }
    let m = &report.metrics;
    let mut t = Table::new(
        &format!("serve-bench: {} via {}", report.benchmark, report.placer),
        &["metric", "value"],
    );
    t.row_strs(&["requests", &report.requests.to_string()]);
    t.row_strs(&["wall clock", &fmt_secs(report.wall_s)]);
    t.row_strs(&[
        "placements/sec",
        &format!("{:.1}", report.placements_per_sec),
    ]);
    t.row_strs(&[
        "cache hit rate",
        &format!("{:.1}%", m.cache_hit_rate() * 100.0),
    ]);
    t.row_strs(&["latency p50", &fmt_secs(m.p50_latency_s)]);
    t.row_strs(&["latency p99", &fmt_secs(m.p99_latency_s)]);
    t.row_strs(&[
        "modes (hit/incremental/full)",
        &format!("{}/{}/{}", m.cache_hits, m.incremental, m.full),
    ]);
    if m.incremental > 0 && m.full > 0 {
        t.row_strs(&[
            "incremental vs full mean",
            &format!(
                "{} vs {}",
                fmt_secs(m.incremental_mean_latency_s),
                fmt_secs(m.full_mean_latency_s)
            ),
        ]);
    }
    t.row_strs(&[
        "batches (mean size)",
        &format!(
            "{} ({:.2})",
            m.batches,
            m.batched_requests as f64 / m.batches.max(1) as f64
        ),
    ]);
    t.row_strs(&["errors", &m.errors.to_string()]);
    t.row_strs(&["engine cache evictions", &m.engine_cache.evictions.to_string()]);
    t.print();
    Ok(())
}

fn cmd_trace(args: &Args) -> baechi::Result<()> {
    let cfg = config_from(args)?;
    let (report, trace) = run_traced(&cfg)?;
    let path = args.get_or("out", "trace.json");
    write_trace(&path, &trace)?;
    if args.has("json") {
        println!("{}", report.to_json().pretty());
    }
    Ok(())
}

fn cmd_explain(args: &Args) -> baechi::Result<()> {
    use baechi::explain::BlameCategory;
    let cfg = config_from(args)?;
    let top_k = args.get_usize("top", 10)?;
    let er = run_explained(&cfg)?;

    if let Some(other) = args.get("diff-placer") {
        let mut cfg2 = config_from(args)?;
        cfg2.placer = PlacerKind::parse(&other)?;
        let er2 = run_explained(&cfg2)?;
        return explain_diff(args, &cfg, &er, &er2);
    }
    if let Some(query) = args.get("op") {
        return explain_op(args, &cfg, &er, &query);
    }
    if args.has("json") {
        println!("{}", er.to_json(top_k).pretty());
        return Ok(());
    }

    let a = &er.attribution;
    // The acceptance invariant: the four categories telescope back to
    // the simulated makespan. Surface a violation loudly — CI smoke
    // runs this command.
    let residual = a.residual();
    if residual.abs() > 1e-9 * a.makespan.abs().max(1.0) {
        return Err(BaechiError::runtime(format!(
            "critical-path attribution does not sum to the makespan: \
             residual {residual:e} over {}",
            a.makespan
        )));
    }
    let mut t = Table::new(
        &format!(
            "explain: {} via {}",
            er.report.benchmark, er.report.placer
        ),
        &["metric", "value"],
    );
    let makespan_label = if er.report.sim.ok() {
        "simulated makespan"
    } else {
        "simulated makespan (OOM, partial)"
    };
    t.row_strs(&[makespan_label, &fmt_secs(a.makespan)]);
    for (name, cat) in [
        ("  compute", BlameCategory::Compute),
        ("  transfer", BlameCategory::Transfer),
        ("  queue wait", BlameCategory::QueueWait),
        ("  idle", BlameCategory::Idle),
    ] {
        let secs = match cat {
            BlameCategory::Compute => a.compute,
            BlameCategory::Transfer => a.transfer,
            BlameCategory::QueueWait => a.queue_wait,
            BlameCategory::Idle => a.idle,
        };
        t.row_strs(&[
            name,
            &format!("{} ({:.1}%)", fmt_secs(secs), a.fraction(cat) * 100.0),
        ]);
    }
    t.row_strs(&["sum check", &format!("ok (residual {residual:.1e})")]);
    t.row_strs(&["path elements", &a.path.len().to_string()]);
    for d in &a.per_device {
        t.row_strs(&[
            &format!("gpu{} on path", d.device),
            &format!(
                "{} compute, {} queued, {} idle",
                fmt_secs(d.compute),
                fmt_secs(d.queue_wait),
                fmt_secs(d.idle)
            ),
        ]);
    }
    for l in &a.per_link {
        t.row_strs(&[
            &format!("link {} on path", l.link),
            &format!(
                "{} transfer, {} queued",
                fmt_secs(l.transfer),
                fmt_secs(l.queue_wait)
            ),
        ]);
    }
    for (i, top) in a.top_ops.iter().take(top_k).enumerate() {
        t.row_strs(&[
            &format!("critical op {}", i + 1),
            &format!("{} on gpu{} ({})", top.name, top.device, fmt_secs(top.seconds)),
        ]);
    }
    let counts = er.decisions.counts_by_reason();
    if er.decisions.decisions.is_empty() {
        t.row_strs(&["decisions", "none recorded (placer has no explain hooks)"]);
    } else {
        for (reason, n) in counts.iter().filter(|(_, n)| *n > 0) {
            t.row_strs(&[&format!("decisions: {}", reason.as_str()), &n.to_string()]);
        }
    }
    for note in &er.decisions.notes {
        t.row_strs(&["note", note]);
    }
    t.print();
    Ok(())
}

/// `baechi explain --op <name-or-id>`: one op's decision record.
fn explain_op(
    args: &Args,
    cfg: &BaechiConfig,
    er: &baechi::coordinator::ExplainReport,
    query: &str,
) -> baechi::Result<()> {
    let graph = cfg.benchmark.graph();
    let decision = er
        .decisions
        .decisions
        .iter()
        .rev()
        .find(|d| d.name == query)
        .or_else(|| {
            query
                .parse::<usize>()
                .ok()
                .and_then(|id| er.decisions.for_node(baechi::graph::NodeId(id)))
        })
        .ok_or_else(|| {
            BaechiError::invalid(format!(
                "no decision recorded for op '{query}' in {} \
                 ({} decisions; ops are matched by exact name or node id)",
                graph.name,
                er.decisions.decisions.len()
            ))
        })?;
    if args.has("json") {
        println!("{}", decision.to_json().pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("decision: {} (node {})", decision.name, decision.node.0),
        &["metric", "value"],
    );
    t.row_strs(&["chosen device", &format!("gpu{}", decision.chosen)]);
    t.row_strs(&["reason", decision.reason.as_str()]);
    for c in &decision.candidates {
        let bid = match c.est {
            Some(est) => format!(
                "EST {} (data ready {}, device free {})",
                fmt_secs(est),
                fmt_secs(c.data_ready),
                fmt_secs(c.device_free)
            ),
            None => format!("does not fit (short {})", fmt_bytes(c.memory_deficit)),
        };
        let marker = if c.device == decision.chosen { " *" } else { "" };
        t.row_strs(&[&format!("gpu{}{marker}", c.device), &bid]);
    }
    t.print();
    Ok(())
}

/// `baechi explain --diff-placer <p>`: where two placers disagree.
fn explain_diff(
    args: &Args,
    cfg: &BaechiConfig,
    a: &baechi::coordinator::ExplainReport,
    b: &baechi::coordinator::ExplainReport,
) -> baechi::Result<()> {
    let graph = cfg.benchmark.graph();
    let moved: Vec<(baechi::graph::NodeId, usize, usize)> = a
        .report
        .device_of
        .iter()
        .filter_map(|(&node, &da)| {
            let db = *b.report.device_of.get(&node)?;
            (da != db).then_some((node, da.0, db.0))
        })
        .collect();
    if args.has("json") {
        let mut j = Json::obj();
        let side = |er: &baechi::coordinator::ExplainReport| {
            let mut o = Json::obj();
            o.set("placer", er.report.placer.as_str())
                .set("makespan", er.attribution.makespan)
                .set("oom", !er.report.sim.ok());
            o
        };
        j.set("a", side(a)).set("b", side(b)).set(
            "moved",
            Json::Arr(
                moved
                    .iter()
                    .map(|&(node, da, db)| {
                        let mut o = Json::obj();
                        o.set("node", node.0)
                            .set("name", graph.node(node).name.as_str())
                            .set("a_device", da)
                            .set("b_device", db);
                        o
                    })
                    .collect(),
            ),
        );
        println!("{}", j.pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "explain diff: {} vs {} on {}",
            a.report.placer, b.report.placer, a.report.benchmark
        ),
        &["metric", "value"],
    );
    let step = |er: &baechi::coordinator::ExplainReport| {
        if er.report.sim.ok() {
            fmt_secs(er.report.sim.makespan)
        } else {
            "OOM".to_string()
        }
    };
    t.row_strs(&[&format!("makespan {}", a.report.placer), &step(a)]);
    t.row_strs(&[&format!("makespan {}", b.report.placer), &step(b)]);
    t.row_strs(&[
        "ops moved",
        &format!("{} of {}", moved.len(), a.report.device_of.len()),
    ]);
    let top_k = args.get_usize("top", 10)?;
    for &(node, da, db) in moved.iter().take(top_k) {
        t.row_strs(&[&graph.node(node).name, &format!("gpu{da} → gpu{db}")]);
    }
    if moved.len() > top_k {
        t.row_strs(&["…", &format!("{} more (raise --top)", moved.len() - top_k)]);
    }
    t.print();
    Ok(())
}

fn cmd_info(args: &Args) -> baechi::Result<()> {
    let cfg = config_from(args)?;
    let g = cfg.benchmark.graph();
    let opt = baechi::optimizer::optimize(&g, &cfg.opt);
    let mut t = Table::new(&format!("graph: {}", g.name), &["metric", "value"]);
    t.row_strs(&["ops", &g.len().to_string()]);
    t.row_strs(&["edges", &g.edge_count().to_string()]);
    t.row_strs(&["ops after optimization", &opt.graph.len().to_string()]);
    t.row_strs(&["total compute", &fmt_secs(g.total_compute())]);
    t.row_strs(&[
        "critical path (no comm)",
        &fmt_secs(g.critical_path(|_| 0.0)?),
    ]);
    t.row_strs(&["permanent memory", &fmt_bytes(g.total_permanent_memory())]);
    t.row_strs(&[
        "rho (comm/compute)",
        &format!("{:.2}", g.rho(|b| cfg.comm.time(b))),
    ]);
    t.print();
    Ok(())
}
