//! Quickstart for the `PlacementEngine` service API: build an engine
//! with the builder, serve typed request → response placements, batch
//! across threads, hit the placement cache, and branch on structured
//! errors — all on the paper's two didactic graphs.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use baechi::engine::{PlacementEngine, PlacementRequest};
use baechi::models::linreg::{fig1_graph, linreg_graph, FIG1_MEM_UNIT};
use baechi::optimizer::OptConfig;
use baechi::profile::{Cluster, CommModel};
use baechi::util::table::Table;
use baechi::BaechiError;

fn main() -> baechi::Result<()> {
    // Abstract units: 1 byte moves in 1 time-unit.
    let unit_comm = CommModel::new(0.0, 1.0).unwrap();

    // ---- build one long-lived engine per target cluster ---------------
    // Figure-1 setting: 3 devices × 4 memory units (+ transfer-buffer
    // headroom, paper §4.2: "usually a device has a few bytes left").
    let cap = 4 * FIG1_MEM_UNIT + 12;
    let engine = PlacementEngine::builder()
        .cluster(Cluster::homogeneous(3, cap, unit_comm))
        .build()?;
    println!("registered placers: {}", engine.registry().names().join(", "));

    // ---- one request/response -----------------------------------------
    // Figure 2: the linear-regression working example placed by m-SCT.
    // The didactic graphs ship pre-reduced, so skip the optimizer.
    let lr_req = PlacementRequest::new(linreg_graph(), "m-sct").with_opt(OptConfig::none());
    let resp = engine.place(&lr_req)?;
    let lr = linreg_graph();
    let mut t = Table::new(
        "Figure 2: linear regression placed by m-SCT (request/response)",
        &["operator", "device"],
    );
    for n in lr.iter_nodes() {
        t.row(&[n.name.clone(), resp.placement.device(n.id).to_string()]);
    }
    t.print();
    // TF colocation constraints hold:
    for (grp, members) in lr.colocation_groups() {
        let d0 = resp.placement.device(members[0]);
        for &m in &members[1..] {
            assert_eq!(resp.placement.device(m), d0, "group {grp} split");
        }
        println!("colocation group '{grp}' intact on {d0}");
    }

    // ---- a batch fanned across threads --------------------------------
    println!();
    let reqs: Vec<PlacementRequest> = ["m-topo", "m-etf", "m-sct"]
        .iter()
        .map(|p| PlacementRequest::new(fig1_graph(), p).with_opt(OptConfig::none()))
        .collect();
    let mut t = Table::new(
        "Figure 1 graph on 3 × 4-unit devices (place_batch)",
        &["placer", "makespan (time units)", "devices", "outcome"],
    );
    for result in engine.place_batch(&reqs) {
        let r = result?;
        let outcome = match &r.sim {
            Some(s) if s.ok() => "runs within the cap".to_string(),
            Some(s) => format!("{:?}", s.oom),
            None => "-".into(),
        };
        t.row(&[
            r.placer.clone(),
            format!("{:.0}", r.placement.predicted_makespan),
            r.devices_used.to_string(),
            outcome,
        ]);
        if let Some(s) = r.sim.as_ref().filter(|s| s.ok()) {
            for (i, &p) in s.peak_memory.iter().enumerate() {
                assert!(p <= cap, "gpu{i} over the cap");
            }
        }
    }
    t.print();

    // ---- the cache: identical requests are memoized -------------------
    let again = engine.place(&lr_req)?;
    let stats = engine.cache_stats();
    println!(
        "\ncache: {} hits / {} misses ({} responses memoized)",
        stats.hits,
        stats.misses,
        engine.cache_len()
    );
    assert!(stats.hits >= 1, "second identical request must hit");
    assert_eq!(again.placement.device_of, resp.placement.device_of);

    // ---- typed errors: branch on the failure mode, not on strings -----
    // A cluster too small for the Fig. 1 graph (6 < 11 memory units).
    let tight = PlacementEngine::builder()
        .cluster(Cluster::homogeneous(3, 2 * FIG1_MEM_UNIT, unit_comm))
        .build()?;
    match tight.place(&PlacementRequest::new(fig1_graph(), "m-etf").with_opt(OptConfig::none())) {
        Err(BaechiError::Oom {
            op,
            best_device,
            deficit,
        }) => println!(
            "typed OOM: operator '{op}' does not fit; closest device {best_device:?} \
             is {deficit} bytes short"
        ),
        Ok(_) => panic!("11-unit graph cannot fit a 6-unit cluster"),
        Err(e) => panic!("expected Oom, got {e}"),
    }
    match tight.place(&PlacementRequest::new(fig1_graph(), "not-a-placer")) {
        Err(BaechiError::UnknownPlacer { name, known }) => {
            println!("typed UnknownPlacer: '{name}' (known: {})", known.join("|"))
        }
        Ok(_) => panic!("bogus placer resolved"),
        Err(e) => panic!("expected UnknownPlacer, got {e}"),
    }

    println!("\nOK: engine served requests, batches, cache hits, and typed errors.");
    Ok(())
}
