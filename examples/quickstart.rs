//! Quickstart: place the paper's two didactic graphs and reproduce the
//! Figure-1 story — classical SCT (no memory awareness) OOMs on
//! memory-capped devices while m-SCT succeeds with a slightly longer
//! makespan.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use baechi::graph::DeviceId;
use baechi::models::linreg::{fig1_graph, linreg_graph, FIG1_MEM_UNIT};
use baechi::placer::{msct::MSct, Placer};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::util::table::Table;

fn main() -> anyhow::Result<()> {
    // ---- Figure 1: SCT vs m-SCT under a memory cap -------------------
    let g = fig1_graph();
    // Abstract units: 1 byte moves in 1 time-unit.
    let unit_comm = CommModel::new(0.0, 1.0);

    // "Classical SCT": memory-oblivious — place with effectively infinite
    // memory, then *run* it on capped devices. The cap is 4 memory units
    // plus a few bytes of transfer-buffer headroom (paper §4.2: "usually
    // a device has at least a few bytes left").
    let cap = 4 * FIG1_MEM_UNIT + 12;
    let free_cluster = Cluster::homogeneous(3, 1_000_000 * FIG1_MEM_UNIT, unit_comm);
    let capped_cluster = Cluster::homogeneous(3, cap, unit_comm);
    let sct_placement = MSct::with_lp().place(&g, &free_cluster)?;
    let sct_on_capped = simulate(&g, &capped_cluster, &sct_placement.device_of, SimConfig::default());

    // m-SCT: memory-aware placement on the capped devices.
    let msct_placement = MSct::with_lp().place(&g, &capped_cluster)?;
    let msct_run = simulate(&g, &capped_cluster, &msct_placement.device_of, SimConfig::default());

    let mut t = Table::new(
        "Figure 1: classical SCT vs m-SCT (per-device memory = 4 units)",
        &["schedule", "makespan", "outcome"],
    );
    t.row(&[
        "SCT (memory-oblivious)".into(),
        format!("{:.0}", sct_placement.predicted_makespan),
        match &sct_on_capped.oom {
            Some(o) => format!("OOM (gpu{})", o.device),
            None => "fits (lucky layout)".into(),
        },
    ]);
    t.row(&[
        "m-SCT (memory-aware)".into(),
        format!("{:.0}", msct_run.makespan),
        "succeeds".into(),
    ]);
    t.print();
    assert!(msct_run.ok(), "m-SCT must run within the cap");
    for (i, &p) in msct_run.peak_memory.iter().enumerate() {
        println!(
            "  gpu{i} peak memory: {:.2} / 4 units",
            p as f64 / FIG1_MEM_UNIT as f64
        );
        assert!(p <= cap);
    }

    // ---- Figure 2: the linear-regression working example --------------
    println!();
    let lr = linreg_graph();
    let cluster = Cluster::homogeneous(2, 100, unit_comm);
    let placement = MSct::with_lp().place(&lr, &cluster)?;
    let mut t = Table::new(
        "Figure 2: linear regression placed by m-SCT on 2 devices",
        &["operator", "device"],
    );
    for n in lr.iter_nodes() {
        t.row(&[n.name.clone(), placement.device(n.id).to_string()]);
    }
    t.print();
    // TF colocation constraints hold:
    for (grp, members) in lr.colocation_groups() {
        let d0 = placement.device(members[0]);
        for &m in &members[1..] {
            assert_eq!(placement.device(m), d0, "group {grp} split");
        }
        println!("colocation group '{grp}' intact on {}", d0);
    }
    // DOT export for inspection.
    let dot = lr.to_dot(Some(
        &placement
            .device_of
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect::<std::collections::BTreeMap<_, DeviceId>>(),
    ));
    std::fs::write("/tmp/baechi_linreg.dot", dot)?;
    println!("wrote /tmp/baechi_linreg.dot");
    Ok(())
}
