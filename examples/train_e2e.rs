//! End-to-end driver (DESIGN.md per-experiment index, row "e2e"):
//! place the AOT-compiled MLP with m-SCT, train it for a few hundred
//! steps of *real* PJRT execution across device worker threads, log the
//! loss curve, and validate the distributed numerics against the fused
//! `train_step` oracle artifact.
//!
//! Requires `make artifacts`.
//!
//! ```text
//! cargo run --release --example train_e2e [-- --steps 300 --devices 2]
//! ```

use baechi::exec::plan::MlpPlan;
use baechi::exec::trainer::{train_distributed, train_oracle, ModelMeta, TrainConfig};
use baechi::models::Benchmark;
use baechi::placer::msct::MSct;
use baechi::placer::Placer;
use baechi::profile::{Cluster, CommModel};
use baechi::runtime::artifact::ArtifactRegistry;
use baechi::util::cli::{Args, OptSpec};

fn main() -> baechi::Result<()> {
    let specs = [
        OptSpec {
            name: "steps",
            help: "training steps",
            takes_value: true,
            default: Some("300"),
        },
        OptSpec {
            name: "devices",
            help: "simulated devices (worker threads)",
            takes_value: true,
            default: Some("2"),
        },
        OptSpec {
            name: "lr",
            help: "learning rate",
            takes_value: true,
            default: Some("0.1"),
        },
    ];
    let args = Args::parse(&specs)?;
    let steps = args.get_usize("steps", 300)?;
    let devices = args.get_usize("devices", 2)?;
    let lr = args.get_f64("lr", 0.1)? as f32;

    let dir = ArtifactRegistry::default_dir();
    if !dir.join("manifest.json").exists() {
        return Err(baechi::BaechiError::io(format!(
            "no artifacts at {} — run `make artifacts` first",
            dir.display()
        )));
    }
    let meta = ModelMeta::load(&dir)?;
    println!(
        "model: {}-layer MLP, batch {}, dims {:?}",
        meta.n_layers(),
        meta.batch,
        meta.layer_dims
    );

    // Place the module graph with m-SCT on memory-tight devices so the
    // placer genuinely splits the model.
    let graph = Benchmark::Mlp.graph();
    // Tight devices: the ~370 KiB model cannot fit on one, so the
    // placer must genuinely split it.
    let cluster = Cluster::homogeneous(devices, 320 << 10, CommModel::pcie_via_host());
    // Fuse each module (params + fwd + bwd + optimizer) before placing,
    // exactly like the coordinator pipeline — modules move as units.
    let opt = baechi::optimizer::optimize(&graph, &baechi::optimizer::OptConfig::default());
    let placement = MSct::default().place(&opt.graph, &cluster)?;
    let full = baechi::optimizer::expand_placement(&graph, &opt, &placement.device_of);
    let placement = baechi::placer::Placement {
        device_of: full,
        ..placement
    };
    let plan = MlpPlan::from_placement(&graph, &placement, devices, meta.n_layers())?;
    println!(
        "m-SCT placement ({} ms): layers → {:?}, loss → gpu{}, {} cross-device hops/step",
        (placement.placement_time * 1e3).round(),
        plan.layer_dev,
        plan.loss_dev,
        plan.cross_device_hops(),
    );

    // Train distributed (real PJRT compute; channel interconnect).
    let cfg = TrainConfig {
        steps,
        lr,
        ..Default::default()
    };
    let report = train_distributed(&plan, &cfg)?;
    println!(
        "\ndistributed run: {} steps in {:.2}s = {:.1} steps/s on {} worker threads",
        steps, report.wall_time, report.steps_per_sec, devices
    );
    println!("loss curve:");
    let stride = (steps / 15).max(1);
    for (s, l) in report.losses.iter().enumerate() {
        if s % stride == 0 || s == steps - 1 {
            let bar = "▉".repeat(((l / report.losses[0]) * 40.0).clamp(0.0, 60.0) as usize);
            println!("  step {s:>5}  loss {l:>8.4}  {bar}");
        }
    }
    let head: f32 = report.losses[..10.min(steps)].iter().sum::<f32>() / 10.0_f32.min(steps as f32);
    let tail: f32 =
        report.losses[steps.saturating_sub(10)..].iter().sum::<f32>() / 10.0_f32.min(steps as f32);
    println!("mean loss: first 10 steps {head:.4} → last 10 steps {tail:.4}");

    // Oracle validation: fused train_step artifact, same data + params.
    let oracle_steps = steps.min(20);
    let oracle = train_oracle(&TrainConfig {
        steps: oracle_steps,
        lr,
        ..Default::default()
    })?;
    let mut max_err = 0.0f32;
    for (a, b) in report.losses.iter().zip(&oracle) {
        max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
    }
    println!(
        "oracle check over {oracle_steps} steps: max relative loss deviation {max_err:.2e}"
    );
    if max_err >= 1e-3 {
        return Err(baechi::BaechiError::runtime(
            "distributed run diverged from oracle",
        ));
    }
    if tail >= head {
        return Err(baechi::BaechiError::runtime("loss did not decrease"));
    }
    println!("OK: distributed placed training matches the fused oracle and learns.");
    Ok(())
}
