//! GNMT placement walkthrough (paper Table 4 scenario): compare all
//! placers on the full-memory 4-GPU cluster, show the optimizer's op
//! reduction, and the speedup over single-GPU.
//!
//! ```text
//! cargo run --release --example gnmt_placement [-- --batch 128 --len 40]
//! ```

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::util::cli::{Args, OptSpec};
use baechi::util::table::{fmt_secs, Table};

fn main() -> baechi::Result<()> {
    let specs = [
        OptSpec {
            name: "batch",
            help: "batch size",
            takes_value: true,
            default: Some("128"),
        },
        OptSpec {
            name: "len",
            help: "sequence length",
            takes_value: true,
            default: Some("40"),
        },
    ];
    let args = Args::parse(&specs)?;
    let batch = args.get_usize("batch", 128)?;
    let seq_len = args.get_usize("len", 40)?;
    let benchmark = Benchmark::Gnmt { batch, seq_len };

    let mut rows = Vec::new();
    for placer in [
        PlacerKind::Single,
        PlacerKind::Expert,
        PlacerKind::MTopo,
        PlacerKind::MEtf,
        PlacerKind::MSct,
    ] {
        let cfg = BaechiConfig::paper_default(benchmark, placer);
        let r = run(&cfg)?;
        rows.push(r);
    }
    let single_step = rows[0].step_time();

    let mut t = Table::new(
        &format!("GNMT bs{batch} len{seq_len} on 4 × 8 GiB GPUs (Table 4 scenario)"),
        &[
            "placer",
            "ops placed",
            "placement time",
            "step time",
            "speedup vs single",
        ],
    );
    for r in &rows {
        let speedup = match (single_step, r.step_time()) {
            (Some(s), Some(x)) => format!("{:+.1}%", (s / x - 1.0) * 100.0),
            _ => "-".into(),
        };
        t.row(&[
            r.placer.clone(),
            r.placed_ops.to_string(),
            fmt_secs(r.placement_time),
            r.step_time().map(fmt_secs).unwrap_or_else(|| "OOM".into()),
            speedup,
        ]);
    }
    t.print();
    println!(
        "graph optimizer: {} ops → {} placed groups",
        rows.last().unwrap().original_ops,
        rows.last().unwrap().placed_ops
    );
    Ok(())
}
