//! Inception-V3 under memory pressure (paper Table 5 + Fig. 7 scenario):
//! at a 30 % memory cap the single-GPU and expert placements OOM while
//! m-TOPO / m-ETF / m-SCT place successfully; print the step times and
//! the per-device peak-memory load balance.
//!
//! ```text
//! cargo run --release --example inception_placement [-- --batch 32 --fraction 0.3]
//! ```

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::util::cli::{Args, OptSpec};
use baechi::util::table::{fmt_bytes, fmt_secs, Table};

fn main() -> baechi::Result<()> {
    let specs = [
        OptSpec {
            name: "batch",
            help: "batch size",
            takes_value: true,
            default: Some("32"),
        },
        OptSpec {
            name: "fraction",
            help: "memory fraction per device",
            takes_value: true,
            default: Some("0.3"),
        },
    ];
    let args = Args::parse(&specs)?;
    let batch = args.get_usize("batch", 32)?;
    let fraction = args.get_f64("fraction", 0.3)?;
    let benchmark = Benchmark::InceptionV3 { batch };

    let mut t = Table::new(
        &format!("Inception-V3 bs{batch} at {:.0}% memory (4 GPUs)", fraction * 100.0),
        &["placer", "placement time", "step time", "devices"],
    );
    let mut load_balance: Option<(String, Vec<u64>, u64)> = None;
    for placer in [
        PlacerKind::Single,
        PlacerKind::Expert,
        PlacerKind::MTopo,
        PlacerKind::MEtf,
        PlacerKind::MSct,
    ] {
        let cfg = BaechiConfig::paper_default(benchmark, placer).with_memory_fraction(fraction);
        match run(&cfg) {
            Ok(r) => {
                t.row(&[
                    r.placer.clone(),
                    fmt_secs(r.placement_time),
                    r.step_time().map(fmt_secs).unwrap_or_else(|| "OOM".into()),
                    r.devices_used.to_string(),
                ]);
                if placer == PlacerKind::MSct && r.sim.ok() {
                    load_balance = Some((r.placer, r.peak_memory, r.device_capacity));
                }
            }
            Err(e) => {
                t.row(&[
                    placer.name().into(),
                    "-".into(),
                    format!("placement OOM ({e})"),
                    "-".into(),
                ]);
            }
        }
    }
    t.print();

    // Fig. 7: memory load balance.
    if let Some((name, peaks, cap)) = load_balance {
        let mut t = Table::new(
            &format!("Fig. 7 load balance ({name}) — bars normalized to the cap"),
            &["device", "peak", "of cap", "bar"],
        );
        for (i, &p) in peaks.iter().enumerate() {
            let frac = p as f64 / cap as f64;
            t.row(&[
                format!("gpu{i}"),
                fmt_bytes(p),
                format!("{:.0}%", frac * 100.0),
                "█".repeat((frac * 40.0).round() as usize),
            ]);
        }
        t.print();
    }
    Ok(())
}
