#!/usr/bin/env python3
"""Validate a `baechi trace` export as Chrome trace-event JSON.

Checks, beyond "it parses":

* the document is an object with a ``traceEvents`` list;
* every complete (``ph: "X"``) event has a non-negative ``ts`` and
  ``dur``, a ``pid``/``tid``, and a name;
* on the pipeline track (pid 1), every engine stage span (optimize /
  place / expand / simulate) nests inside the request span of the same
  trace id, within a 0.5 µs rounding slack;
* span ``args`` are well-formed: always an object when present; on the
  simulated-plan track (pid 2) ops carry an integer ``node`` and
  transfers (``xfer …``) integer ``src``/``dst``/``bytes``/``link``;
* critical-path annotations are consistent: ``crit`` only appears on
  the simulated-plan track, is literally ``true``, and is always paired
  with a known ``crit_category``.

Exit status 0 when valid, 1 with a diagnostic otherwise. Used by ci.sh
on the `baechi trace` smoke artifact.
"""

import json
import sys

PIPELINE_PID = 1
SIM_PID = 2
STAGES = {"optimize", "place", "expand", "simulate"}
CRIT_CATEGORIES = {"compute", "transfer", "queue_wait", "idle"}
SLACK_US = 0.5


def validate(doc):
    """Return (errors, summary): a list of problems and a stats string."""
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return ["no traceEvents array"], ""
    events = doc["traceEvents"]

    errors = []
    complete = [e for e in events if isinstance(e, dict) and e.get("ph") == "X"]
    if not complete:
        return ["no complete (ph=X) events"], ""
    for e in complete:
        name = e.get("name")
        if not name:
            errors.append(f"unnamed X event: {e}")
            continue
        for key in ("ts", "dur"):
            v = e.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{name}: bad {key} {v!r}")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                errors.append(f"{name}: bad {key} {e.get(key)!r}")

    crit = 0
    for e in complete:
        name = e.get("name", "?")
        args = e.get("args")
        if args is None:
            continue
        if not isinstance(args, dict):
            errors.append(f"{name}: args is not an object: {args!r}")
            continue
        if e.get("pid") == SIM_PID:
            keys = (
                ("src", "dst", "bytes", "link", "node")
                if str(name).startswith("xfer ")
                else ("node",)
            )
            for key in keys:
                if not isinstance(args.get(key), int):
                    errors.append(f"{name}: sim event missing int args.{key}")
        if "crit" in args or "crit_category" in args:
            if e.get("pid") != SIM_PID:
                errors.append(f"{name}: crit annotation off the simulated-plan track")
            if args.get("crit") is not True:
                errors.append(f"{name}: args.crit must be true, got {args.get('crit')!r}")
            if args.get("crit_category") not in CRIT_CATEGORIES:
                errors.append(
                    f"{name}: bad args.crit_category {args.get('crit_category')!r}"
                )
            crit += 1

    pipeline = [e for e in complete if e.get("pid") == PIPELINE_PID]
    requests = {}
    for e in pipeline:
        if e.get("name") == "request":
            trace = e.get("args", {}).get("trace")
            if trace is None:
                errors.append("request event without args.trace")
            else:
                requests[trace] = e

    checked = 0
    for e in pipeline:
        if e.get("name") not in STAGES:
            continue
        trace = e.get("args", {}).get("trace")
        if trace is None:
            errors.append(f"{e['name']} event without args.trace")
            continue
        req = requests.get(trace)
        if req is None:
            errors.append(f"{e['name']} (trace {trace}) has no request span")
            continue
        if e["ts"] < req["ts"] - SLACK_US:
            errors.append(f"{e['name']} starts before its request span")
        if e["ts"] + e["dur"] > req["ts"] + req["dur"] + SLACK_US:
            errors.append(f"{e['name']} ends after its request span")
        checked += 1
    if not requests:
        errors.append("pipeline track has no request spans")
    if not checked:
        errors.append("pipeline track has no stage spans")

    summary = (
        f"{len(complete)} events, {len(requests)} request span(s), "
        f"{checked} nested stage span(s), {crit} critical-path annotation(s)"
    )
    return errors, summary


def main(argv):
    if len(argv) != 1:
        print("usage: validate_trace.py <trace.json>", file=sys.stderr)
        return 2
    path = argv[0]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"validate_trace: {path}: {e}", file=sys.stderr)
        return 1
    errors, summary = validate(doc)
    if errors:
        for e in errors:
            print(f"validate_trace: {e}", file=sys.stderr)
        return 1
    print(f"{path}: ok — {summary}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
