#!/usr/bin/env python3
"""Validate a `baechi trace` export as Chrome trace-event JSON.

Checks, beyond "it parses":

* the document is an object with a ``traceEvents`` list;
* every complete (``ph: "X"``) event has a non-negative ``ts`` and
  ``dur``, a ``pid``/``tid``, and a name;
* on the pipeline track (pid 1), every engine stage span (optimize /
  place / expand / simulate) nests inside the request span of the same
  trace id, within a 0.5 µs rounding slack.

Exit status 0 when valid, 1 with a diagnostic otherwise. Used by ci.sh
on the `baechi trace` smoke artifact.
"""

import json
import sys

PIPELINE_PID = 1
STAGES = {"optimize", "place", "expand", "simulate"}
SLACK_US = 0.5


def fail(msg):
    print(f"validate_trace: {msg}", file=sys.stderr)
    sys.exit(1)


def main(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")

    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail(f"{path}: no traceEvents array")
    events = doc["traceEvents"]

    complete = [e for e in events if e.get("ph") == "X"]
    if not complete:
        fail(f"{path}: no complete (ph=X) events")
    for e in complete:
        name = e.get("name")
        if not name:
            fail(f"unnamed X event: {e}")
        for key in ("ts", "dur"):
            v = e.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{name}: bad {key} {v!r}")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                fail(f"{name}: bad {key} {e.get(key)!r}")

    pipeline = [e for e in complete if e["pid"] == PIPELINE_PID]
    requests = {}
    for e in pipeline:
        if e["name"] == "request":
            trace = e.get("args", {}).get("trace")
            if trace is None:
                fail("request event without args.trace")
            requests[trace] = e

    checked = 0
    for e in pipeline:
        if e["name"] not in STAGES:
            continue
        trace = e.get("args", {}).get("trace")
        if trace is None:
            fail(f"{e['name']} event without args.trace")
        req = requests.get(trace)
        if req is None:
            fail(f"{e['name']} (trace {trace}) has no request span")
        if e["ts"] < req["ts"] - SLACK_US:
            fail(f"{e['name']} starts before its request span")
        if e["ts"] + e["dur"] > req["ts"] + req["dur"] + SLACK_US:
            fail(f"{e['name']} ends after its request span")
        checked += 1
    if not requests:
        fail("pipeline track has no request spans")
    if not checked:
        fail("pipeline track has no stage spans")

    print(
        f"{path}: ok — {len(complete)} events, {len(requests)} request "
        f"span(s), {checked} nested stage span(s)"
    )


if __name__ == "__main__":
    if len(sys.argv) != 2:
        fail("usage: validate_trace.py <trace.json>")
    main(sys.argv[1])
