#!/usr/bin/env python3
"""Compare fresh bench JSON against committed baselines and fail on regression.

Usage:
    check_bench.py --fresh DIR --baselines DIR [--tolerance 0.15] [--update DIR]

Both directories hold ``BENCH_<name>.json`` documents in the schema the
Rust benches emit (``util/bench.rs``):

    {"bench": "<name>", "schema": 1, "rows": [{...}, ...], "summary": {...}}

Every baseline file must have a fresh counterpart, and every baseline row
(matched by its identity keys, default ``["name"]``) must appear in the
fresh run; numeric fields are compared within a relative tolerance.
Fresh rows or files without a baseline are reported but not gated — the
baseline is the contract, the fresh run may grow beyond it.

A ``tolerances.json`` next to the baselines tunes the gate:

    {
      "default": 0.15,              // relative tolerance
      "abs_floor": 1e-12,           // |f-b| <= tol * max(|b|, abs_floor)
      "overrides": {"^p99_.*$": 0.5},   // per-field-name regex -> tolerance
      "ignore": ["^iters$"],        // field-name regexes never compared
      "identity": {"BENCH_serving.json": ["model", "mutation_rate"]}
    }

A baseline document with a top-level ``"bootstrap": true`` is a
*structural* baseline: recorded before trustworthy numbers existed (e.g.
no toolchain on the authoring machine). It gates only the shape — bench
name, schema, and that every baseline row identity is present in the
fresh run — never the values. ``--update DIR`` then writes promoted
baselines carrying the fresh run's real numbers (bootstrap flag dropped),
ready to be committed once a trusted runner has produced them.

Exit status: 0 = all gates pass, 1 = regression/missing data, 2 = usage.
"""

import argparse
import json
import os
import re
import sys

DEFAULT_TOLERANCE = 0.15
DEFAULT_ABS_FLOOR = 1e-12


class GateConfig:
    """Parsed tolerances.json (all fields optional)."""

    def __init__(self, raw=None, default_tolerance=None):
        raw = raw or {}
        self.default = float(
            default_tolerance
            if default_tolerance is not None
            else raw.get("default", DEFAULT_TOLERANCE)
        )
        self.abs_floor = float(raw.get("abs_floor", DEFAULT_ABS_FLOOR))
        self.overrides = [
            (re.compile(pat), float(tol))
            for pat, tol in raw.get("overrides", {}).items()
        ]
        self.ignore = [re.compile(pat) for pat in raw.get("ignore", [])]
        self.identity = {
            fname: list(keys) for fname, keys in raw.get("identity", {}).items()
        }

    @classmethod
    def load(cls, baselines_dir, default_tolerance=None):
        path = os.path.join(baselines_dir, "tolerances.json")
        raw = None
        if os.path.exists(path):
            with open(path) as f:
                raw = json.load(f)
        return cls(raw, default_tolerance)

    def tolerance_for(self, key):
        for pat, tol in self.overrides:
            if pat.fullmatch(key):
                return tol
        return self.default

    def is_ignored(self, key):
        return any(pat.fullmatch(key) for pat in self.ignore)

    def identity_keys(self, filename):
        return self.identity.get(filename, ["name"])


def load_doc(path):
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "rows" not in doc:
        raise ValueError(f"{path}: not a bench document (no 'rows')")
    return doc


def row_identity(row, keys):
    """Identity tuple of a row; None when an identity key is missing."""
    try:
        return tuple((k, row[k]) for k in keys)
    except KeyError:
        return None


def is_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare_rows(base_row, fresh_row, cfg, label, issues):
    """Append an issue string per out-of-tolerance field."""
    for key, b in base_row.items():
        if cfg.is_ignored(key):
            continue
        if key not in fresh_row:
            issues.append(f"{label}: field '{key}' missing from fresh row")
            continue
        f = fresh_row[key]
        if is_number(b) and is_number(f):
            tol = cfg.tolerance_for(key)
            allowed = tol * max(abs(b), cfg.abs_floor)
            if abs(f - b) > allowed:
                delta = (f - b) / b if b else float("inf")
                issues.append(
                    f"{label}: '{key}' = {f:g} vs baseline {b:g} "
                    f"({delta:+.1%}, tolerance ±{tol:.0%})"
                )
        elif b != f:
            issues.append(f"{label}: '{key}' = {f!r} vs baseline {b!r}")


def compare_docs(filename, base, fresh, cfg):
    """Gate one baseline document. Returns (issues, notes)."""
    issues, notes = [], []
    if base.get("bench") != fresh.get("bench"):
        issues.append(
            f"{filename}: bench name {fresh.get('bench')!r} "
            f"vs baseline {base.get('bench')!r}"
        )
    if base.get("schema") != fresh.get("schema"):
        issues.append(
            f"{filename}: schema {fresh.get('schema')!r} "
            f"vs baseline {base.get('schema')!r}"
        )
    keys = cfg.identity_keys(filename)
    bootstrap = bool(base.get("bootstrap"))

    fresh_by_id = {}
    for row in fresh.get("rows", []):
        ident = row_identity(row, keys)
        if ident is not None:
            fresh_by_id[ident] = row

    gated = 0
    for row in base.get("rows", []):
        ident = row_identity(row, keys)
        if ident is None:
            issues.append(
                f"{filename}: baseline row lacks identity keys {keys}: {row}"
            )
            continue
        label = f"{filename}[{', '.join(str(v) for _, v in ident)}]"
        if ident not in fresh_by_id:
            issues.append(f"{label}: row missing from fresh run")
            continue
        gated += 1
        if not bootstrap:
            compare_rows(row, fresh_by_id[ident], cfg, label, issues)

    extra = len(fresh_by_id) - sum(
        1
        for row in base.get("rows", [])
        if row_identity(row, keys) in fresh_by_id
    )
    if extra > 0:
        notes.append(f"{filename}: {extra} fresh row(s) not gated (no baseline)")
    if bootstrap:
        notes.append(
            f"{filename}: bootstrap baseline — structure gated ({gated} rows), "
            "values not yet trusted"
        )
    return issues, notes


def promote(fresh, base):
    """The baseline a trusted fresh run promotes to (bootstrap flag gone)."""
    doc = dict(fresh)
    doc.pop("bootstrap", None)
    # Keep a provenance hint when the previous baseline was a bootstrap.
    if base.get("bootstrap"):
        doc["promoted_from_bootstrap"] = True
    return doc


def run(fresh_dir, baselines_dir, default_tolerance=None, update_dir=None):
    """Gate every baseline; returns (exit_code, report_lines)."""
    lines = []
    cfg = GateConfig.load(baselines_dir, default_tolerance)
    baseline_files = sorted(
        f
        for f in os.listdir(baselines_dir)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not baseline_files:
        lines.append(f"FAIL: no BENCH_*.json baselines in {baselines_dir}")
        return 1, lines

    all_issues = []
    for fname in baseline_files:
        base = load_doc(os.path.join(baselines_dir, fname))
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(fresh_path):
            all_issues.append(f"{fname}: fresh run produced no such file")
            lines.append(f"  {fname:40s} MISSING")
            continue
        fresh = load_doc(fresh_path)
        issues, notes = compare_docs(fname, base, fresh, cfg)
        status = "FAIL" if issues else ("BOOTSTRAP-OK" if base.get("bootstrap") else "OK")
        lines.append(f"  {fname:40s} {status}")
        for n in notes:
            lines.append(f"    note: {n}")
        for i in issues:
            lines.append(f"    regression: {i}")
        all_issues.extend(issues)
        if update_dir is not None and not issues:
            os.makedirs(update_dir, exist_ok=True)
            out = os.path.join(update_dir, fname)
            with open(out, "w") as f:
                json.dump(promote(fresh, base), f, indent=2, sort_keys=True)
                f.write("\n")
            lines.append(f"    promoted: {out}")

    fresh_only = sorted(
        f
        for f in os.listdir(fresh_dir)
        if f.startswith("BENCH_")
        and f.endswith(".json")
        and f not in baseline_files
    )
    for fname in fresh_only:
        lines.append(f"  {fname:40s} (fresh only, not gated)")

    lines.append(
        f"{len(baseline_files)} baseline file(s) gated, "
        f"{len(all_issues)} issue(s)"
    )
    return (1 if all_issues else 0), lines


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--fresh", required=True, help="directory of fresh BENCH_*.json")
    p.add_argument("--baselines", required=True, help="directory of committed baselines")
    p.add_argument(
        "--tolerance",
        type=float,
        default=None,
        help=f"default relative tolerance (default {DEFAULT_TOLERANCE}, "
        "overridden per-field by tolerances.json)",
    )
    p.add_argument(
        "--update",
        metavar="DIR",
        default=None,
        help="write promoted baselines (fresh values, bootstrap flag dropped) here",
    )
    args = p.parse_args(argv)
    for d in (args.fresh, args.baselines):
        if not os.path.isdir(d):
            print(f"error: {d} is not a directory", file=sys.stderr)
            return 2
    code, lines = run(args.fresh, args.baselines, args.tolerance, args.update)
    print("bench gate:")
    for line in lines:
        print(line)
    print("bench gate: " + ("PASS" if code == 0 else "FAIL"))
    return code


if __name__ == "__main__":
    sys.exit(main())
