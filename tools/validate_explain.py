#!/usr/bin/env python3
"""Validate a `baechi explain --json` artifact.

Checks, beyond "it parses":

* the document carries an ``attribution`` object whose four category
  totals (compute / transfer / queue_wait / idle) sum to ``makespan``
  within 1e-9 (relative), matching the Rust-side invariant;
* ``fractions`` lie in [0, 1] and sum to 1 for a non-zero makespan;
* the critical ``path`` is chronological, uses only known categories,
  and (for non-OOM runs) ends at the makespan;
* ``top_ops`` are sorted heaviest-first;
* every decision record names a known reason, the chosen device
  appears among its candidates with a numeric EST (the placer cannot
  have scheduled an unschedulable device), and every candidate carries
  a non-negative ``memory_deficit`` (``est: null`` with deficit 0 is a
  colocation pin to another device, not a memory disqualification).

Exit status 0 when valid, 1 with a diagnostic otherwise. Used by ci.sh
on the `baechi explain` smoke artifact.
"""

import json
import sys

CATEGORIES = ("compute", "transfer", "queue_wait", "idle")
REASONS = {"min-est", "sct-favorite-child", "coarsen-pin", "oom-fallback"}
REL_TOL = 1e-9


def _num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(doc, require_decisions=False):
    """Return a list of problems (empty when the artifact is valid)."""
    errors = []

    def err(msg):
        errors.append(msg)

    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    attr = doc.get("attribution")
    if not isinstance(attr, dict):
        return ["no attribution object"]

    makespan = attr.get("makespan")
    if not _num(makespan) or makespan < 0:
        return [f"bad attribution.makespan {makespan!r}"]
    eps = REL_TOL * max(1.0, abs(makespan))

    total = 0.0
    for cat in CATEGORIES:
        v = attr.get(cat)
        if not _num(v):
            err(f"attribution.{cat} missing or non-numeric: {v!r}")
            continue
        if v < -eps:
            err(f"attribution.{cat} is negative: {v}")
        total += v
    if not errors and abs(total - makespan) > eps:
        err(
            f"attribution does not sum to makespan: "
            f"{total!r} vs {makespan!r} (residual {total - makespan:e})"
        )

    fractions = attr.get("fractions")
    if not isinstance(fractions, dict):
        err("attribution.fractions missing")
    else:
        fsum = 0.0
        for cat in CATEGORIES:
            f = fractions.get(cat)
            if not _num(f) or f < -eps or f > 1 + eps:
                err(f"fractions.{cat} out of [0,1]: {f!r}")
            else:
                fsum += f
        if makespan > 0 and abs(fsum - 1.0) > 1e-6:
            err(f"fractions sum to {fsum}, expected 1")

    path = attr.get("path")
    if not isinstance(path, list):
        err("attribution.path missing")
        path = []
    prev_end = float("-inf")
    for i, step in enumerate(path):
        if not isinstance(step, dict):
            err(f"path[{i}] is not an object")
            continue
        if step.get("category") not in CATEGORIES:
            err(f"path[{i}] has unknown category {step.get('category')!r}")
        start, end = step.get("start"), step.get("end")
        if not (_num(start) and _num(end)) or end < start - eps:
            err(f"path[{i}] has a bad interval [{start!r}, {end!r}]")
            continue
        if start < prev_end - eps:
            err(f"path[{i}] goes backward in time")
        prev_end = end
    if path and not doc.get("oom", False):
        last_end = path[-1].get("end")
        if _num(last_end) and abs(last_end - makespan) > eps:
            err(f"path ends at {last_end}, not the makespan {makespan}")

    top_ops = attr.get("top_ops")
    if not isinstance(top_ops, list):
        err("attribution.top_ops missing")
        top_ops = []
    for i, op in enumerate(top_ops):
        if not isinstance(op, dict) or not op.get("name") or not _num(op.get("seconds")):
            err(f"top_ops[{i}] malformed: {op!r}")
        elif i > 0 and _num(top_ops[i - 1].get("seconds")):
            if op["seconds"] > top_ops[i - 1]["seconds"] + eps:
                err(f"top_ops[{i}] not sorted heaviest-first")

    dec = doc.get("decisions")
    if not isinstance(dec, dict) or not isinstance(dec.get("decisions"), list):
        err("no decisions object")
        records = []
    else:
        records = dec["decisions"]
    if require_decisions and not records:
        err("no decision records (expected some: placer has explain hooks)")
    for i, d in enumerate(records):
        if not isinstance(d, dict):
            err(f"decisions[{i}] is not an object")
            continue
        if d.get("reason") not in REASONS:
            err(f"decisions[{i}] has unknown reason {d.get('reason')!r}")
        cands = d.get("candidates")
        if not isinstance(cands, list) or not cands:
            err(f"decisions[{i}] ({d.get('name')!r}) has no candidates")
            continue
        chosen = d.get("chosen")
        winner = next(
            (c for c in cands if isinstance(c, dict) and c.get("device") == chosen),
            None,
        )
        if winner is None:
            err(f"decisions[{i}] chose device {chosen!r} not among its candidates")
        elif not _num(winner.get("est")):
            err(
                f"decisions[{i}] ({d.get('name')!r}) chose gpu{chosen!r} "
                f"whose candidate has no EST (unschedulable winner)"
            )
        for c in cands:
            if not isinstance(c, dict):
                err(f"decisions[{i}] has a malformed candidate {c!r}")
                continue
            deficit = c.get("memory_deficit")
            if not _num(deficit) or deficit < 0:
                err(
                    f"decisions[{i}] candidate gpu{c.get('device')!r} has a "
                    f"bad memory_deficit {deficit!r}"
                )

    return errors


def main(argv):
    args = [a for a in argv if not a.startswith("--")]
    require_decisions = "--require-decisions" in argv
    if len(args) != 1:
        print(
            "usage: validate_explain.py [--require-decisions] <explain.json>",
            file=sys.stderr,
        )
        return 2
    path = args[0]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"validate_explain: {path}: {e}", file=sys.stderr)
        return 1
    errors = validate(doc, require_decisions=require_decisions)
    if errors:
        for e in errors:
            print(f"validate_explain: {e}", file=sys.stderr)
        return 1
    attr = doc["attribution"]
    n_dec = len(doc.get("decisions", {}).get("decisions", []))
    print(
        f"{path}: ok — makespan {attr['makespan']:.6g}s over "
        f"{len(attr['path'])} path element(s), {n_dec} decision record(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
