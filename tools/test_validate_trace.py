#!/usr/bin/env python3
"""Unit tests for validate_trace.py (stdlib unittest, dict fixtures)."""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import validate_trace


def ev(pid, tid, name, ts, dur, args=None):
    e = {"ph": "X", "pid": pid, "tid": tid, "name": name, "ts": ts, "dur": dur}
    if args is not None:
        e["args"] = args
    return e


def pipeline_pair(trace=1):
    return [
        ev(1, 7, "request", 0.0, 100.0, {"trace": trace, "span": 1}),
        ev(1, 7, "place", 10.0, 50.0, {"trace": trace, "span": 2, "parent": 1}),
    ]


def sim_op(name="matmul", crit=None, ts=0.0, dur=5.0):
    args = {"node": 3, "device": 0}
    if crit is not None:
        args.update(crit)
    return ev(2, 0, name, ts, dur, args)


def sim_xfer(crit=None):
    args = {"node": 3, "src": 0, "dst": 1, "bytes": 64, "link": 2}
    if crit is not None:
        args.update(crit)
    return ev(2, 4, "xfer matmul", 5.0, 3.0, args)


def doc(events):
    return {"traceEvents": events, "displayTimeUnit": "ms"}


class ValidateTraceTest(unittest.TestCase):
    def check(self, events):
        return validate_trace.validate(doc(events))

    def test_valid_trace_passes(self):
        errors, summary = self.check(
            pipeline_pair()
            + [
                sim_op(crit={"crit": True, "crit_category": "compute"}),
                sim_xfer(crit={"crit": True, "crit_category": "transfer"}),
                sim_op(name="add"),
            ]
        )
        self.assertEqual(errors, [])
        self.assertIn("2 critical-path annotation(s)", summary)

    def test_rejects_missing_trace_events(self):
        errors, _ = validate_trace.validate({"foo": 1})
        self.assertTrue(any("traceEvents" in e for e in errors), errors)

    def test_rejects_negative_duration(self):
        errors, _ = self.check(pipeline_pair() + [ev(2, 0, "op", 1.0, -2.0, {"node": 1})])
        self.assertTrue(any("bad dur" in e for e in errors), errors)

    def test_stage_outside_request_span_fails(self):
        events = [
            ev(1, 7, "request", 0.0, 10.0, {"trace": 1}),
            ev(1, 7, "place", 5.0, 50.0, {"trace": 1}),
        ]
        errors, _ = self.check(events)
        self.assertTrue(any("ends after" in e for e in errors), errors)

    def test_stage_without_request_fails(self):
        events = [
            ev(1, 7, "request", 0.0, 10.0, {"trace": 1}),
            ev(1, 7, "place", 1.0, 2.0, {"trace": 99}),
        ]
        errors, _ = self.check(events)
        self.assertTrue(any("no request span" in e for e in errors), errors)

    def test_args_must_be_object(self):
        events = pipeline_pair() + [ev(2, 0, "op", 0.0, 1.0, "not-a-dict")]
        errors, _ = self.check(events)
        self.assertTrue(any("args is not an object" in e for e in errors), errors)

    def test_sim_op_requires_int_node(self):
        events = pipeline_pair() + [ev(2, 0, "op", 0.0, 1.0, {"node": "three"})]
        errors, _ = self.check(events)
        self.assertTrue(any("missing int args.node" in e for e in errors), errors)

    def test_sim_transfer_requires_link_fields(self):
        events = pipeline_pair() + [
            ev(2, 4, "xfer op", 0.0, 1.0, {"node": 1, "src": 0, "dst": 1, "bytes": 64})
        ]
        errors, _ = self.check(events)
        self.assertTrue(any("missing int args.link" in e for e in errors), errors)

    def test_crit_requires_true_and_category(self):
        errors, _ = self.check(
            pipeline_pair() + [sim_op(crit={"crit": 1, "crit_category": "compute"})]
        )
        self.assertTrue(any("must be true" in e for e in errors), errors)
        errors, _ = self.check(
            pipeline_pair() + [sim_op(crit={"crit": True, "crit_category": "luck"})]
        )
        self.assertTrue(any("crit_category" in e for e in errors), errors)
        errors, _ = self.check(pipeline_pair() + [sim_op(crit={"crit": True})])
        self.assertTrue(any("crit_category" in e for e in errors), errors)

    def test_crit_belongs_to_sim_track(self):
        events = [
            ev(1, 7, "request", 0.0, 100.0, {"trace": 1, "crit": True, "crit_category": "compute"}),
            ev(1, 7, "place", 1.0, 2.0, {"trace": 1}),
        ]
        errors, _ = self.check(events)
        self.assertTrue(
            any("off the simulated-plan track" in e for e in errors), errors
        )

    def test_main_exit_codes(self):
        with tempfile.TemporaryDirectory() as tmp:
            good = os.path.join(tmp, "good.json")
            with open(good, "w") as f:
                json.dump(doc(pipeline_pair() + [sim_op()]), f)
            self.assertEqual(validate_trace.main([good]), 0)
            bad = os.path.join(tmp, "bad.json")
            with open(bad, "w") as f:
                json.dump({"traceEvents": []}, f)
            self.assertEqual(validate_trace.main([bad]), 1)
            self.assertEqual(validate_trace.main(["/nonexistent.json"]), 1)
            self.assertEqual(validate_trace.main([]), 2)


if __name__ == "__main__":
    unittest.main()
