#!/usr/bin/env python3
"""Unit tests for validate_explain.py (stdlib unittest, dict fixtures)."""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import validate_explain


def attribution(makespan=4.0, compute=3.0, transfer=0.5, queue_wait=0.25, idle=0.25):
    return {
        "makespan": makespan,
        "compute": compute,
        "transfer": transfer,
        "queue_wait": queue_wait,
        "idle": idle,
        "residual": (compute + transfer + queue_wait + idle) - makespan,
        "fractions": {
            "compute": compute / makespan,
            "transfer": transfer / makespan,
            "queue_wait": queue_wait / makespan,
            "idle": idle / makespan,
        },
        "per_device": [{"device": 0, "compute": compute, "queue_wait": 0.0, "idle": 0.0}],
        "per_link": [],
        "top_ops": [
            {"node": 1, "name": "matmul", "device": 0, "seconds": 2.0, "start": 1.0, "end": 3.0},
            {"node": 0, "name": "add", "device": 0, "seconds": 1.0, "start": 0.0, "end": 1.0},
        ],
        "path": [
            {"kind": "op", "node": 0, "device": 0, "category": "compute",
             "start": 0.0, "end": 1.0, "gap_before": 0.0},
            {"kind": "transfer", "node": 0, "src": 0, "dst": 1, "bytes": 64,
             "category": "transfer", "start": 1.0, "end": 1.5, "gap_before": 0.0},
            {"kind": "op", "node": 1, "device": 1, "category": "compute",
             "start": 1.5, "end": makespan, "gap_before": 0.0},
        ],
    }


def decision(name="matmul", chosen=0, reason="min-est", deficit=64):
    return {
        "node": 1,
        "name": name,
        "chosen": chosen,
        "reason": reason,
        "candidates": [
            {"device": 0, "est": 1.5, "data_ready": 1.5, "device_free": 1.0,
             "memory_deficit": 0},
            {"device": 1, "est": None, "data_ready": 0.5, "device_free": 0.0,
             "memory_deficit": deficit},
        ],
    }


def doc(**overrides):
    d = {
        "benchmark": "mlp",
        "placer": "m-sct",
        "oom": False,
        "attribution": attribution(),
        "decisions": {"decisions": [decision()], "notes": []},
    }
    d.update(overrides)
    return d


class ValidateExplainTest(unittest.TestCase):
    def test_valid_artifact_passes(self):
        self.assertEqual(validate_explain.validate(doc()), [])

    def test_requires_attribution(self):
        errors = validate_explain.validate({"decisions": {"decisions": []}})
        self.assertTrue(any("attribution" in e for e in errors), errors)

    def test_sum_violation_is_caught(self):
        bad = doc(attribution=attribution(compute=2.0))  # off by 1s
        errors = validate_explain.validate(bad)
        self.assertTrue(any("sum to makespan" in e for e in errors), errors)

    def test_sum_tolerates_1e9_relative(self):
        a = attribution()
        a["compute"] += 1e-10 * a["makespan"]
        self.assertEqual(validate_explain.validate(doc(attribution=a)), [])

    def test_negative_category_is_caught(self):
        a = attribution(compute=4.5, idle=-0.5)
        errors = validate_explain.validate(doc(attribution=a))
        self.assertTrue(any("negative" in e for e in errors), errors)

    def test_backward_path_is_caught(self):
        a = attribution()
        a["path"][1], a["path"][2] = a["path"][2], a["path"][1]
        errors = validate_explain.validate(doc(attribution=a))
        self.assertTrue(any("backward" in e for e in errors), errors)

    def test_path_must_end_at_makespan_unless_oom(self):
        a = attribution()
        a["path"][-1]["end"] = 3.0
        errors = validate_explain.validate(doc(attribution=a))
        self.assertTrue(any("not the makespan" in e for e in errors), errors)
        # An OOM run legitimately has a truncated schedule.
        self.assertEqual(validate_explain.validate(doc(attribution=a, oom=True)), [])

    def test_unsorted_top_ops_is_caught(self):
        a = attribution()
        a["top_ops"].reverse()
        errors = validate_explain.validate(doc(attribution=a))
        self.assertTrue(any("heaviest-first" in e for e in errors), errors)

    def test_unknown_reason_and_orphan_choice(self):
        d = doc(decisions={"decisions": [decision(reason="vibes", chosen=9)], "notes": []})
        errors = validate_explain.validate(d)
        self.assertTrue(any("unknown reason" in e for e in errors), errors)
        self.assertTrue(any("not among its candidates" in e for e in errors), errors)

    def test_chosen_candidate_must_be_schedulable(self):
        # The winner's candidate must carry a numeric EST; an est:null
        # winner means the placer scheduled an unschedulable device.
        d = doc(decisions={"decisions": [decision(chosen=1)], "notes": []})
        errors = validate_explain.validate(d)
        self.assertTrue(any("unschedulable winner" in e for e in errors), errors)

    def test_colocation_pin_candidate_is_legal(self):
        # est:null with deficit 0 is a colocation pin, not an error.
        d = doc(decisions={"decisions": [decision(deficit=0)], "notes": []})
        self.assertEqual(validate_explain.validate(d), [])

    def test_bad_deficit_is_caught(self):
        d = doc(decisions={"decisions": [decision(deficit=-5)], "notes": []})
        errors = validate_explain.validate(d)
        self.assertTrue(any("bad memory_deficit" in e for e in errors), errors)

    def test_require_decisions_flag(self):
        empty = doc(decisions={"decisions": [], "notes": []})
        self.assertEqual(validate_explain.validate(empty), [])
        errors = validate_explain.validate(empty, require_decisions=True)
        self.assertTrue(any("no decision records" in e for e in errors), errors)

    def test_fractions_must_cover_the_makespan(self):
        a = attribution()
        a["fractions"]["compute"] = 0.1
        errors = validate_explain.validate(doc(attribution=a))
        self.assertTrue(any("fractions sum" in e for e in errors), errors)

    def test_main_exit_codes(self):
        with tempfile.TemporaryDirectory() as tmp:
            good = os.path.join(tmp, "good.json")
            with open(good, "w") as f:
                json.dump(doc(), f)
            self.assertEqual(validate_explain.main([good]), 0)
            self.assertEqual(validate_explain.main([good, "--require-decisions"]), 0)
            bad = os.path.join(tmp, "bad.json")
            with open(bad, "w") as f:
                json.dump(doc(attribution=attribution(compute=0.0)), f)
            self.assertEqual(validate_explain.main([bad]), 1)
            self.assertEqual(validate_explain.main(["/nonexistent.json"]), 1)
            self.assertEqual(validate_explain.main([]), 2)


if __name__ == "__main__":
    unittest.main()
