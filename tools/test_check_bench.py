#!/usr/bin/env python3
"""Unit tests for check_bench.py (stdlib unittest, fixture JSON on disk)."""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench


def doc(name, rows, bootstrap=False, schema=1):
    d = {"bench": name, "schema": schema, "rows": rows}
    if bootstrap:
        d["bootstrap"] = True
    return d


class Tree:
    """Writes fixture docs into fresh/ and baselines/ under a tempdir."""

    def __init__(self, tmp):
        self.fresh = os.path.join(tmp, "fresh")
        self.baselines = os.path.join(tmp, "baselines")
        os.makedirs(self.fresh)
        os.makedirs(self.baselines)

    def write(self, where, fname, payload):
        with open(os.path.join(where, fname), "w") as f:
            json.dump(payload, f)


class CheckBenchTest(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.t = Tree(self._tmp.name)

    def tearDown(self):
        self._tmp.cleanup()

    def gate(self, **kw):
        return check_bench.run(self.t.fresh, self.t.baselines, **kw)

    def test_pass_within_tolerance(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.0}])
        )
        self.t.write(
            self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.1}])
        )
        code, lines = self.gate()
        self.assertEqual(code, 0, lines)

    def test_fail_beyond_tolerance(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.0}])
        )
        self.t.write(
            self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.3}])
        )
        code, lines = self.gate()
        self.assertEqual(code, 1)
        self.assertTrue(any("mean_s" in l for l in lines), lines)

    def test_cli_tolerance_widens_gate(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.0}])
        )
        self.t.write(
            self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "mean_s": 1.3}])
        )
        code, _ = self.gate(default_tolerance=0.5)
        self.assertEqual(code, 0)

    def test_per_key_override_and_ignore(self):
        self.t.write(
            self.t.baselines,
            "tolerances.json",
            {
                "default": 0.15,
                "overrides": {"^p99_.*$": 1.0},
                "ignore": ["^iters$"],
            },
        )
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "p99_latency_s": 1.0, "iters": 100}]),
        )
        # p99 doubled (allowed by override), iters wildly off (ignored).
        self.t.write(
            self.t.fresh,
            "BENCH_a.json",
            doc("a", [{"name": "x", "p99_latency_s": 1.9, "iters": 3}]),
        )
        code, lines = self.gate()
        self.assertEqual(code, 0, lines)

    def test_baseline_row_missing_from_fresh_fails(self):
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "v": 1.0}, {"name": "y", "v": 2.0}]),
        )
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}]))
        code, lines = self.gate()
        self.assertEqual(code, 1)
        self.assertTrue(any("missing from fresh run" in l for l in lines), lines)

    def test_fresh_extra_rows_are_not_gated(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}])
        )
        self.t.write(
            self.t.fresh,
            "BENCH_a.json",
            doc("a", [{"name": "x", "v": 1.0}, {"name": "z", "v": 999.0}]),
        )
        code, lines = self.gate()
        self.assertEqual(code, 0)
        self.assertTrue(any("not gated" in l for l in lines), lines)

    def test_missing_fresh_file_fails(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}])
        )
        code, lines = self.gate()
        self.assertEqual(code, 1)
        self.assertTrue(any("MISSING" in l for l in lines), lines)

    def test_custom_identity_keys(self):
        self.t.write(
            self.t.baselines,
            "tolerances.json",
            {"identity": {"BENCH_serving.json": ["model", "shards"]}},
        )
        self.t.write(
            self.t.baselines,
            "BENCH_serving.json",
            doc("serving", [{"model": "gnmt", "shards": 8, "rate": 100.0}]),
        )
        self.t.write(
            self.t.fresh,
            "BENCH_serving.json",
            doc("serving", [{"model": "gnmt", "shards": 8, "rate": 101.0}]),
        )
        code, lines = self.gate()
        self.assertEqual(code, 0, lines)

    def test_non_numeric_mismatch_fails(self):
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "mode": "fast"}]),
        )
        self.t.write(
            self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "mode": "slow"}])
        )
        code, lines = self.gate()
        self.assertEqual(code, 1)
        self.assertTrue(any("'mode'" in l for l in lines), lines)

    def test_bootstrap_gates_structure_only(self):
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "v": 1.0}], bootstrap=True),
        )
        # Wildly different value: fine under a bootstrap baseline.
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "v": 50.0}]))
        code, lines = self.gate()
        self.assertEqual(code, 0, lines)
        self.assertTrue(any("BOOTSTRAP-OK" in l for l in lines), lines)

    def test_bootstrap_still_fails_on_missing_row(self):
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "v": 1.0}], bootstrap=True),
        )
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "other", "v": 1.0}]))
        code, _ = self.gate()
        self.assertEqual(code, 1)

    def test_update_promotes_fresh_values(self):
        self.t.write(
            self.t.baselines,
            "BENCH_a.json",
            doc("a", [{"name": "x", "v": 1.0}], bootstrap=True),
        )
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "v": 7.0}]))
        update = os.path.join(self._tmp.name, "promoted")
        code, _ = self.gate(update_dir=update)
        self.assertEqual(code, 0)
        with open(os.path.join(update, "BENCH_a.json")) as f:
            promoted = json.load(f)
        self.assertNotIn("bootstrap", promoted)
        self.assertTrue(promoted["promoted_from_bootstrap"])
        self.assertEqual(promoted["rows"][0]["v"], 7.0)

    def test_no_update_written_on_failure(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}])
        )
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "v": 9.0}]))
        update = os.path.join(self._tmp.name, "promoted")
        code, _ = self.gate(update_dir=update)
        self.assertEqual(code, 1)
        self.assertFalse(os.path.exists(os.path.join(update, "BENCH_a.json")))

    def test_empty_baselines_dir_fails(self):
        code, lines = self.gate()
        self.assertEqual(code, 1)
        self.assertTrue(any("no BENCH_" in l for l in lines), lines)

    def test_main_exit_codes(self):
        self.t.write(
            self.t.baselines, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}])
        )
        self.t.write(self.t.fresh, "BENCH_a.json", doc("a", [{"name": "x", "v": 1.0}]))
        self.assertEqual(
            check_bench.main(["--fresh", self.t.fresh, "--baselines", self.t.baselines]),
            0,
        )
        self.assertEqual(
            check_bench.main(["--fresh", "/nonexistent", "--baselines", self.t.baselines]),
            2,
        )


if __name__ == "__main__":
    unittest.main()
